//! Minimal JSON value, writer, and parser.
//!
//! The offline crate set has no `serde`, so `BENCH_*.json` trajectories
//! are emitted and re-read through this hand-rolled subset: objects
//! (key order preserved — emitted files are diffable), arrays, strings,
//! f64 numbers, booleans and null. Numbers are written with Rust's
//! shortest-round-trip `Display`, so emit → parse → emit is a fixpoint.

/// One JSON value. Objects keep insertion order so emitted reports are
/// stable and text-diffable across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Member lookup on an object (`None` on other variants).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Member lookup that errors with the missing key's name.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            Json::Num(v) => Ok(*v),
            other => Err(format!("expected number, got {other:?}")),
        }
    }

    /// Non-negative integer (counters, counts). JSON numbers are f64, so
    /// this is exact for values below 2^53 — far above any counter this
    /// harness produces.
    pub fn as_u64(&self) -> Result<u64, String> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            return Err(format!("expected non-negative integer, got {v}"));
        }
        Ok(v as u64)
    }

    pub fn as_usize(&self) -> Result<usize, String> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(format!("expected bool, got {other:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str, String> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(format!("expected string, got {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], String> {
        match self {
            Json::Arr(items) => Ok(items),
            other => Err(format!("expected array, got {other:?}")),
        }
    }

    /// Render with 2-space indentation and a trailing newline.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    /// Render on a single line with no padding — one JSONL record.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(members) => {
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => write_seq(out, depth, '[', ']', items.len(), |out, i| {
                items[i].write(out, depth + 1)
            }),
            Json::Obj(members) => write_seq(out, depth, '{', '}', members.len(), |out, i| {
                write_str(out, &members[i].0);
                out.push_str(": ");
                members[i].1.write(out, depth + 1);
            }),
        }
    }
}

/// Shorthand for building an object literal in emitting code.
pub fn obj(members: Vec<(&str, Json)>) -> Json {
    Json::Obj(members.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_seq(
    out: &mut String,
    depth: usize,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize),
) {
    if len == 0 {
        out.push(open);
        out.push(close);
        return;
    }
    out.push(open);
    for i in 0..len {
        out.push('\n');
        for _ in 0..(depth + 1) * 2 {
            out.push(' ');
        }
        item(out, i);
        if i + 1 < len {
            out.push(',');
        }
    }
    out.push('\n');
    for _ in 0..depth * 2 {
        out.push(' ');
    }
    out.push(close);
}

fn write_num(out: &mut String, v: f64) {
    // JSON has no NaN/Inf; the harness never produces them, but a
    // defensive null beats emitting an unparseable file.
    if v.is_finite() {
        out.push_str(&v.to_string());
    } else {
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse one JSON document (trailing whitespace allowed, nothing else).
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: text.as_bytes(), at: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.at != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.at));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(b' ' | b'\t' | b'\n' | b'\r') = self.bytes.get(self.at) {
            self.at += 1;
        }
    }

    fn peek(&self) -> Result<u8, String> {
        self.bytes.get(self.at).copied().ok_or_else(|| "unexpected end of input".to_string())
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek()? != b {
            return Err(format!("expected {:?} at byte {}", b as char, self.at));
        }
        self.at += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            other => Err(format!("unexpected byte {:?} at {}", other as char, self.at)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.at..].starts_with(word.as_bytes()) {
            self.at += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.at))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.at;
        while let Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') = self.bytes.get(self.at) {
            self.at += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.at]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number {text:?}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek()? {
                b'"' => {
                    self.at += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.at += 1;
                    match self.peek()? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.at + 4 >= self.bytes.len() {
                                return Err("truncated \\u escape".to_string());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.at + 1..self.at + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                            // Surrogate pairs never occur in our own
                            // output; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.at += 4;
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                    self.at += 1;
                }
                _ => {
                    // Consume one UTF-8 scalar (input came from &str, so
                    // boundaries are valid).
                    let rest = std::str::from_utf8(&self.bytes[self.at..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().ok_or("unexpected end of string")?;
                    out.push(c);
                    self.at += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.at += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.at += 1,
                b']' => {
                    self.at += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected ',' or ']', got {:?}", other as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.at += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            members.push((key, value));
            self.skip_ws();
            match self.peek()? {
                b',' => self.at += 1,
                b'}' => {
                    self.at += 1;
                    return Ok(Json::Obj(members));
                }
                other => return Err(format!("expected ',' or '}}', got {:?}", other as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_fixpoint() {
        let v = obj(vec![
            ("name", Json::Str("quick \"run\"\n".to_string())),
            ("count", Json::Num(16.0)),
            ("wall", Json::Num(0.123456)),
            ("flag", Json::Bool(true)),
            ("nothing", Json::Null),
            ("items", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
            ("empty", Json::Obj(vec![])),
        ]);
        let text = v.pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.pretty(), text);
    }

    #[test]
    fn object_preserves_order_and_lookup() {
        let v = parse(r#"{"b": 1, "a": 2}"#).unwrap();
        match &v {
            Json::Obj(m) => assert_eq!(m[0].0, "b"),
            _ => panic!(),
        }
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 2.0);
        assert!(v.get("missing").is_none());
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn numbers_parse_exactly() {
        assert_eq!(parse("42").unwrap().as_u64().unwrap(), 42);
        assert_eq!(parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert!(parse("1.5").unwrap().as_u64().is_err());
        assert!(parse("-3").unwrap().as_u64().is_err());
        // Exact for the full counter range this harness emits.
        let big = (1u64 << 53) - 1;
        assert_eq!(parse(&big.to_string()).unwrap().as_u64().unwrap(), big);
    }

    #[test]
    fn compact_is_one_line_and_parses_back() {
        let v = obj(vec![
            ("rank", Json::Num(0.0)),
            ("name", Json::Str("spike \"x\"".to_string())),
            ("items", Json::Arr(vec![Json::Num(1.0), Json::Bool(false), Json::Null])),
            ("empty", Json::Obj(vec![])),
        ]);
        let line = v.compact();
        assert!(!line.contains('\n'));
        assert_eq!(parse(&line).unwrap(), v);
        assert_eq!(Json::Arr(vec![]).compact(), "[]");
    }

    #[test]
    fn malformed_inputs_error() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "\"unterminated", "1 2", "{]"] {
            assert!(parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("tab\there \\ \"quote\" \u{1}".to_string());
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }
}
