//! PJRT runtime: loads the AOT artifacts (`python/compile/aot.py` →
//! `artifacts/*.hlo.txt`) and executes them from the Rust hot path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Python never runs at
//! simulation time — artifacts are produced once by `make artifacts`.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), while our
//! simulated-MPI ranks are threads. `XlaService` therefore owns the
//! client on one dedicated executor thread — the software analogue of
//! "one accelerator shared by all ranks of a node" — and rank threads
//! talk to it through a cloneable `XlaHandle`.

mod pjrt_stub;
mod service;

// The real `xla` crate is not in the offline crate set; `pjrt_stub`
// mirrors the API subset we call and errors at client construction.
// Swap this alias for `use xla;` once the real bindings are available.
use pjrt_stub as xla;

pub use service::{spawn_mock_service, spawn_service, NeuronInputs, StagedReply, XlaHandle};

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::neuron::params::NUM_PARAMS;

/// Outputs of one neuron-update execution (padded batch truncated to n).
pub struct NeuronOutputs {
    pub v: Vec<f32>,
    pub u: Vec<f32>,
    pub ca: Vec<f32>,
    pub z_ax: Vec<f32>,
    pub z_de: Vec<f32>,
    pub z_di: Vec<f32>,
    pub fired: Vec<f32>,
}

/// The artifact registry + compiled executables (single-threaded owner).
pub struct XlaRuntime {
    client: xla::PjRtClient,
    /// batch size -> compiled neuron-update executable.
    neuron: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// candidate count -> compiled gauss-probs executable.
    gauss: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Load every artifact listed in `<dir>/manifest.txt` and compile it
    /// on the PJRT CPU client.
    pub fn load(dir: &str) -> Result<XlaRuntime> {
        let manifest = Path::new(dir).join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?}; run `make artifacts` first"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        let mut neuron = BTreeMap::new();
        let mut gauss = BTreeMap::new();
        for line in text.lines() {
            let mut parts = line.split_whitespace();
            let (Some(kind), Some(n), Some(file)) =
                (parts.next(), parts.next(), parts.next())
            else {
                continue;
            };
            let n: usize = n.parse().context("manifest batch size")?;
            let path = Path::new(dir).join(file);
            let path_str = path.to_str().context("artifact path")?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| anyhow!("parsing {path_str}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {file}: {e}"))?;
            match kind {
                "neuron_update" => {
                    neuron.insert(n, exe);
                }
                "gauss_probs" => {
                    gauss.insert(n, exe);
                }
                other => bail!("unknown artifact kind {other:?} in manifest"),
            }
        }
        if neuron.is_empty() {
            bail!("no neuron_update artifacts in {dir}");
        }
        Ok(XlaRuntime { client, neuron, gauss })
    }

    /// Batch sizes available for the neuron update.
    pub fn neuron_batches(&self) -> Vec<usize> {
        self.neuron.keys().copied().collect()
    }

    /// Smallest lowered batch size >= n.
    fn pick_batch(map: &BTreeMap<usize, xla::PjRtLoadedExecutable>, n: usize) -> Result<usize> {
        map.range(n..)
            .next()
            .map(|(&b, _)| b)
            .ok_or_else(|| anyhow!("no artifact batch >= {n} (have {:?})", map.keys()))
    }

    /// Execute one fused neuron-update step. All input slices length n;
    /// the batch is zero-padded to the next lowered size.
    #[allow(clippy::too_many_arguments)]
    pub fn neuron_update(
        &self,
        v: &[f32],
        u: &[f32],
        ca: &[f32],
        z_ax: &[f32],
        z_de: &[f32],
        z_di: &[f32],
        i_syn: &[f32],
        noise: &[f32],
        params: &[f32; NUM_PARAMS],
    ) -> Result<NeuronOutputs> {
        let n = v.len();
        let batch = Self::pick_batch(&self.neuron, n)?;
        let exe = &self.neuron[&batch];

        let pad = |xs: &[f32]| -> xla::Literal {
            if xs.len() == batch {
                xla::Literal::vec1(xs)
            } else {
                let mut padded = Vec::with_capacity(batch);
                padded.extend_from_slice(xs);
                padded.resize(batch, 0.0);
                xla::Literal::vec1(&padded)
            }
        };
        let inputs = [
            pad(v),
            pad(u),
            pad(ca),
            pad(z_ax),
            pad(z_de),
            pad(z_di),
            pad(i_syn),
            pad(noise),
            xla::Literal::vec1(&params[..]),
        ];
        let result = exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("neuron_update execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("neuron_update readback: {e}"))?;
        let outs = result.to_tuple().map_err(|e| anyhow!("neuron_update tuple: {e}"))?;
        if outs.len() != 7 {
            bail!("expected 7 outputs, got {}", outs.len());
        }
        let mut vecs: Vec<Vec<f32>> = Vec::with_capacity(7);
        for o in outs {
            let mut xs = o.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
            xs.truncate(n);
            vecs.push(xs);
        }
        let fired = vecs.pop().unwrap();
        let z_di = vecs.pop().unwrap();
        let z_de = vecs.pop().unwrap();
        let z_ax = vecs.pop().unwrap();
        let ca = vecs.pop().unwrap();
        let u = vecs.pop().unwrap();
        let v = vecs.pop().unwrap();
        Ok(NeuronOutputs { v, u, ca, z_ax, z_de, z_di, fired })
    }

    /// Execute one Gaussian probability row over `tx.len()` candidates
    /// (zero-padded; padding has vacancy 0 so its probability is 0).
    pub fn gauss_probs(
        &self,
        src_pos: [f32; 3],
        sigma: f32,
        tx: &[f32],
        ty: &[f32],
        tz: &[f32],
        vac: &[f32],
    ) -> Result<Vec<f32>> {
        let n = tx.len();
        let batch = Self::pick_batch(&self.gauss, n)?;
        let exe = &self.gauss[&batch];
        let pad = |xs: &[f32]| {
            let mut padded = Vec::with_capacity(batch);
            padded.extend_from_slice(xs);
            padded.resize(batch, 0.0);
            xla::Literal::vec1(&padded)
        };
        let inputs = [
            xla::Literal::vec1(&src_pos[..]),
            xla::Literal::vec1(&[sigma][..]),
            pad(tx),
            pad(ty),
            pad(tz),
            pad(vac),
        ];
        let result = exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("gauss_probs execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("gauss_probs readback: {e}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("gauss_probs tuple: {e}"))?;
        let mut xs = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
        xs.truncate(n);
        Ok(xs)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
