//! XLA executor service: one dedicated thread owns the (non-`Send`)
//! PJRT client; rank threads submit work through a cloneable handle.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::{NeuronOutputs, XlaRuntime};
use crate::neuron::params::NUM_PARAMS;

enum Request {
    NeuronUpdate {
        inputs: Box<NeuronInputs>,
        reply: mpsc::Sender<Result<NeuronOutputs>>,
    },
    GaussProbs {
        src_pos: [f32; 3],
        sigma: f32,
        tx: Vec<f32>,
        ty: Vec<f32>,
        tz: Vec<f32>,
        vac: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Batches {
        reply: mpsc::Sender<Vec<usize>>,
    },
    Shutdown,
}

pub struct NeuronInputs {
    pub v: Vec<f32>,
    pub u: Vec<f32>,
    pub ca: Vec<f32>,
    pub z_ax: Vec<f32>,
    pub z_de: Vec<f32>,
    pub z_di: Vec<f32>,
    pub i_syn: Vec<f32>,
    pub noise: Vec<f32>,
    pub params: [f32; NUM_PARAMS],
}

/// Cloneable, `Send` handle to the XLA service thread.
#[derive(Clone)]
pub struct XlaHandle {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
}

impl XlaHandle {
    /// Execute one fused neuron-update step on the service thread.
    pub fn neuron_update(&self, inputs: NeuronInputs) -> Result<NeuronOutputs> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::NeuronUpdate { inputs: Box::new(inputs), reply })
            .map_err(|_| anyhow!("XLA service is gone"))?;
        rx.recv().map_err(|_| anyhow!("XLA service dropped the reply"))?
    }

    /// Execute one Gaussian probability row on the service thread.
    pub fn gauss_probs(
        &self,
        src_pos: [f32; 3],
        sigma: f32,
        tx_: Vec<f32>,
        ty: Vec<f32>,
        tz: Vec<f32>,
        vac: Vec<f32>,
    ) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::GaussProbs { src_pos, sigma, tx: tx_, ty, tz, vac, reply })
            .map_err(|_| anyhow!("XLA service is gone"))?;
        rx.recv().map_err(|_| anyhow!("XLA service dropped the reply"))?
    }

    /// Batch sizes the loaded neuron-update artifacts cover.
    pub fn neuron_batches(&self) -> Result<Vec<usize>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::Batches { reply })
            .map_err(|_| anyhow!("XLA service is gone"))?;
        rx.recv().map_err(|_| anyhow!("XLA service dropped the reply"))
    }

    /// Ask the service thread to exit (idempotent; also happens when the
    /// last handle is dropped and the channel closes).
    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Request::Shutdown);
    }
}

/// Load artifacts from `dir`, compile them on a fresh service thread,
/// and return a handle. Fails fast if loading/compilation fails.
pub fn spawn_service(dir: &str) -> Result<XlaHandle> {
    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let dir = dir.to_string();
    std::thread::Builder::new()
        .name("xla-service".into())
        .spawn(move || {
            let runtime = match XlaRuntime::load(&dir) {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    rt
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::NeuronUpdate { inputs, reply } => {
                        let i = &*inputs;
                        let out = runtime.neuron_update(
                            &i.v, &i.u, &i.ca, &i.z_ax, &i.z_de, &i.z_di, &i.i_syn,
                            &i.noise, &i.params,
                        );
                        let _ = reply.send(out);
                    }
                    Request::GaussProbs { src_pos, sigma, tx, ty, tz, vac, reply } => {
                        let _ =
                            reply.send(runtime.gauss_probs(src_pos, sigma, &tx, &ty, &tz, &vac));
                    }
                    Request::Batches { reply } => {
                        let _ = reply.send(runtime.neuron_batches());
                    }
                    Request::Shutdown => break,
                }
            }
        })
        .expect("spawning xla-service thread");
    ready_rx.recv().map_err(|_| anyhow!("XLA service died during startup"))??;
    Ok(XlaHandle { tx: Arc::new(Mutex::new(tx)) })
}
