//! XLA executor service: one dedicated thread owns the (non-`Send`)
//! PJRT client; rank threads submit work through a cloneable handle.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::{NeuronOutputs, XlaRuntime};
use crate::neuron::params::NUM_PARAMS;

/// Reply payload of the staged neuron-update path: both staging boxes
/// travel back to the caller with the outputs refilled in place, so the
/// same two allocations ping-pong between kernel and service forever.
pub type StagedReply = Result<(Box<NeuronInputs>, Box<NeuronOutputs>)>;

enum Request {
    NeuronUpdate {
        inputs: Box<NeuronInputs>,
        reply: mpsc::Sender<Result<NeuronOutputs>>,
    },
    NeuronUpdateStaged {
        inputs: Box<NeuronInputs>,
        outputs: Box<NeuronOutputs>,
        reply: mpsc::Sender<StagedReply>,
    },
    GaussProbs {
        src_pos: [f32; 3],
        sigma: f32,
        tx: Vec<f32>,
        ty: Vec<f32>,
        tz: Vec<f32>,
        vac: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Batches {
        reply: mpsc::Sender<Vec<usize>>,
    },
    Shutdown,
}

pub struct NeuronInputs {
    pub v: Vec<f32>,
    pub u: Vec<f32>,
    pub ca: Vec<f32>,
    pub z_ax: Vec<f32>,
    pub z_de: Vec<f32>,
    pub z_di: Vec<f32>,
    pub i_syn: Vec<f32>,
    pub noise: Vec<f32>,
    pub params: [f32; NUM_PARAMS],
}

/// Cloneable, `Send` handle to the XLA service thread.
#[derive(Clone)]
pub struct XlaHandle {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
}

impl XlaHandle {
    /// Execute one fused neuron-update step on the service thread.
    pub fn neuron_update(&self, inputs: NeuronInputs) -> Result<NeuronOutputs> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::NeuronUpdate { inputs: Box::new(inputs), reply })
            .map_err(|_| anyhow!("XLA service is gone"))?;
        rx.recv().map_err(|_| anyhow!("XLA service dropped the reply"))?
    }

    /// Staged variant of [`neuron_update`](Self::neuron_update): the
    /// caller owns both staging boxes and a persistent reply channel;
    /// the service refills `outputs` in place (capacity preserved) and
    /// ships both boxes back through `reply` — no staging memory is
    /// allocated on either side after the first step.
    pub fn neuron_update_staged(
        &self,
        inputs: Box<NeuronInputs>,
        outputs: Box<NeuronOutputs>,
        reply: mpsc::Sender<StagedReply>,
    ) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(Request::NeuronUpdateStaged { inputs, outputs, reply })
            .map_err(|_| anyhow!("XLA service is gone"))
    }

    /// Execute one Gaussian probability row on the service thread.
    pub fn gauss_probs(
        &self,
        src_pos: [f32; 3],
        sigma: f32,
        tx_: Vec<f32>,
        ty: Vec<f32>,
        tz: Vec<f32>,
        vac: Vec<f32>,
    ) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::GaussProbs { src_pos, sigma, tx: tx_, ty, tz, vac, reply })
            .map_err(|_| anyhow!("XLA service is gone"))?;
        rx.recv().map_err(|_| anyhow!("XLA service dropped the reply"))?
    }

    /// Batch sizes the loaded neuron-update artifacts cover.
    pub fn neuron_batches(&self) -> Result<Vec<usize>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::Batches { reply })
            .map_err(|_| anyhow!("XLA service is gone"))?;
        rx.recv().map_err(|_| anyhow!("XLA service dropped the reply"))
    }

    /// Ask the service thread to exit (idempotent; also happens when the
    /// last handle is dropped and the channel closes).
    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Request::Shutdown);
    }
}

/// Load artifacts from `dir`, compile them on a fresh service thread,
/// and return a handle. Fails fast if loading/compilation fails.
pub fn spawn_service(dir: &str) -> Result<XlaHandle> {
    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let dir = dir.to_string();
    std::thread::Builder::new()
        .name("xla-service".into())
        .spawn(move || {
            let runtime = match XlaRuntime::load(&dir) {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    rt
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::NeuronUpdate { inputs, reply } => {
                        let i = &*inputs;
                        let out = runtime.neuron_update(
                            &i.v, &i.u, &i.ca, &i.z_ax, &i.z_de, &i.z_di, &i.i_syn,
                            &i.noise, &i.params,
                        );
                        let _ = reply.send(out);
                    }
                    Request::NeuronUpdateStaged { inputs, mut outputs, reply } => {
                        let i = &*inputs;
                        let res = runtime.neuron_update(
                            &i.v, &i.u, &i.ca, &i.z_ax, &i.z_de, &i.z_di, &i.i_syn,
                            &i.noise, &i.params,
                        );
                        let _ = reply.send(res.map(|out| {
                            fill_outputs(&mut outputs, &out);
                            (inputs, outputs)
                        }));
                    }
                    Request::GaussProbs { src_pos, sigma, tx, ty, tz, vac, reply } => {
                        let _ =
                            reply.send(runtime.gauss_probs(src_pos, sigma, &tx, &ty, &tz, &vac));
                    }
                    Request::Batches { reply } => {
                        let _ = reply.send(runtime.neuron_batches());
                    }
                    Request::Shutdown => break,
                }
            }
        })
        .expect("spawning xla-service thread");
    ready_rx.recv().map_err(|_| anyhow!("XLA service died during startup"))??;
    Ok(XlaHandle { tx: Arc::new(Mutex::new(tx)) })
}

/// Refill the staged output box from a freshly computed result without
/// releasing its capacity (keeps the caller's buffers stable).
fn fill_outputs(dst: &mut NeuronOutputs, src: &NeuronOutputs) {
    fn refill(d: &mut Vec<f32>, s: &[f32]) {
        d.clear();
        d.extend_from_slice(s);
    }
    refill(&mut dst.v, &src.v);
    refill(&mut dst.u, &src.u);
    refill(&mut dst.ca, &src.ca);
    refill(&mut dst.z_ax, &src.z_ax);
    refill(&mut dst.z_de, &src.z_de);
    refill(&mut dst.z_di, &src.z_di);
    refill(&mut dst.fired, &src.fired);
}

/// Spawn a service thread that answers neuron-update requests with the
/// native `izhikevich::step` oracle instead of a PJRT runtime — the
/// stubbed XLA backend for tests and differential harnesses on machines
/// without compiled artifacts. Bit-identical to the scalar kernel by
/// construction (it IS the scalar kernel behind the service protocol).
/// `gauss_probs` replies an error; `neuron_batches` replies empty.
pub fn spawn_mock_service() -> XlaHandle {
    use crate::neuron::{izhikevich, NeuronParams, Population};
    use crate::util::Vec3;

    /// Run the native oracle over one staged input set.
    fn mock_update(i: &NeuronInputs) -> NeuronOutputs {
        let n = i.v.len();
        let mut pop = Population {
            first_id: 0,
            positions: vec![Vec3::ZERO; n],
            is_excitatory: vec![true; n],
            v: i.v.clone(),
            u: i.u.clone(),
            ca: i.ca.clone(),
            z_ax: i.z_ax.clone(),
            z_den_exc: i.z_de.clone(),
            z_den_inh: i.z_di.clone(),
            i_syn: i.i_syn.clone(),
            noise: i.noise.clone(),
            fired: vec![false; n],
            epoch_spikes: vec![0; n],
        };
        izhikevich::step(&mut pop, &NeuronParams::from_vec(&i.params));
        NeuronOutputs {
            v: pop.v,
            u: pop.u,
            ca: pop.ca,
            z_ax: pop.z_ax,
            z_de: pop.z_den_exc,
            z_di: pop.z_den_inh,
            fired: pop.fired.iter().map(|&f| if f { 1.0 } else { 0.0 }).collect(),
        }
    }

    let (tx, rx) = mpsc::channel::<Request>();
    std::thread::Builder::new()
        .name("xla-mock-service".into())
        .spawn(move || {
            while let Ok(req) = rx.recv() {
                match req {
                    Request::NeuronUpdate { inputs, reply } => {
                        let _ = reply.send(Ok(mock_update(&inputs)));
                    }
                    Request::NeuronUpdateStaged { inputs, mut outputs, reply } => {
                        let out = mock_update(&inputs);
                        fill_outputs(&mut outputs, &out);
                        let _ = reply.send(Ok((inputs, outputs)));
                    }
                    Request::GaussProbs { reply, .. } => {
                        let _ = reply
                            .send(Err(anyhow!("mock XLA service: gauss_probs is not stubbed")));
                    }
                    Request::Batches { reply } => {
                        let _ = reply.send(Vec::new());
                    }
                    Request::Shutdown => break,
                }
            }
        })
        .expect("spawning xla-mock-service thread");
    XlaHandle { tx: Arc::new(Mutex::new(tx)) }
}
