//! Stub stand-in for the `xla` crate (PJRT bindings).
//!
//! The offline crate set does not ship `xla_extension`, so this module
//! mirrors the exact API surface `XlaRuntime` consumes and fails at
//! client construction with a descriptive error. Everything upstream of
//! the PJRT boundary — manifest parsing, the service thread, the
//! native mirror of the kernels — keeps compiling and running; only
//! `Backend::Xla` execution is unavailable.
//!
//! To re-enable the real runtime: add the `xla` crate to
//! `rust/Cargo.toml`, delete this module, and restore `use xla;` in
//! `runtime/mod.rs`. No other code changes are needed — the stub types
//! are signature-compatible with the subset of `xla` we call.

use std::fmt;

/// Error type standing in for `xla::Error` (only `Display` is consumed).
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT runtime unavailable: this build uses the stub in \
         runtime/pjrt_stub.rs (the offline crate set has no xla_extension). \
         Use the native backend, or add the real `xla` crate to re-enable \
         Backend::Xla."
            .to_string(),
    )
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_xs: &[f32]) -> Literal {
        Literal
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }
}
