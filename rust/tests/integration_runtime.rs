//! Cross-layer integration: the AOT-lowered JAX/Pallas artifacts executed
//! through PJRT must match the native Rust mirror bit-closely, and a full
//! simulation on the XLA backend must agree with the native backend.
//!
//! These tests need the AOT artifacts (`artifacts/manifest.txt`, built
//! by `make artifacts`) and a real PJRT runtime. On a fresh clone
//! neither exists, so each test checks for the manifest first and
//! SKIPS (passes with a message) instead of failing — the rest of the
//! suite stays green without the artifact toolchain.

use ilmi::config::{Backend, SimConfig};
use ilmi::coordinator::{run_simulation, run_simulation_with_xla};
use ilmi::neuron::{izhikevich, NeuronParams, Population};
use ilmi::runtime::{spawn_service, NeuronInputs, XlaHandle};
use ilmi::util::{Rng, Vec3};

/// True when the AOT artifacts are present (cargo runs integration
/// tests from the package root, so `artifacts/` is `rust/artifacts/`).
fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

/// Skip (early-return) the calling test when artifacts are missing.
macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!(
                "SKIP: artifacts/manifest.txt not found — run `make artifacts` \
                 to enable the XLA/PJRT integration tests"
            );
            return;
        }
    };
}

fn service() -> XlaHandle {
    spawn_service("artifacts").expect("run `make artifacts` before cargo test")
}

fn random_pop(n: usize, seed: u64) -> Population {
    let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
    let mut rng = Rng::new(seed);
    let mut pop = Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(100.0), &mut rng);
    for i in 0..n {
        pop.v[i] = rng.uniform(-80.0, 25.0) as f32;
        pop.u[i] = rng.uniform(-20.0, 10.0) as f32;
        pop.ca[i] = rng.uniform(0.0, 1.2) as f32;
        pop.i_syn[i] = rng.uniform(-3.0, 3.0) as f32;
        pop.noise[i] = rng.normal_ms(5.0, 1.0) as f32;
    }
    pop
}

fn assert_close(name: &str, a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= tol * scale,
            "{name}[{i}]: native {x} vs xla {y}"
        );
    }
}

#[test]
fn xla_neuron_update_matches_native_mirror() {
    require_artifacts!();
    let handle = service();
    let params = NeuronParams::default();
    for seed in [1u64, 2, 3] {
        let mut native = random_pop(300, seed); // padded to batch 1024
        let inputs = NeuronInputs {
            v: native.v.clone(),
            u: native.u.clone(),
            ca: native.ca.clone(),
            z_ax: native.z_ax.clone(),
            z_de: native.z_den_exc.clone(),
            z_di: native.z_den_inh.clone(),
            i_syn: native.i_syn.clone(),
            noise: native.noise.clone(),
            params: params.to_vec(),
        };
        let out = handle.neuron_update(inputs).unwrap();
        izhikevich::step(&mut native, &params);
        assert_close("v", &native.v, &out.v, 1e-4);
        assert_close("u", &native.u, &out.u, 1e-4);
        assert_close("ca", &native.ca, &out.ca, 1e-4);
        assert_close("z_ax", &native.z_ax, &out.z_ax, 1e-4);
        assert_close("z_de", &native.z_den_exc, &out.z_de, 1e-4);
        assert_close("z_di", &native.z_den_inh, &out.z_di, 1e-4);
        let native_fired: Vec<f32> =
            native.fired.iter().map(|&f| if f { 1.0 } else { 0.0 }).collect();
        assert_eq!(native_fired, out.fired, "spike decisions must agree exactly");
    }
    handle.shutdown();
}

#[test]
fn xla_neuron_update_iterated_stays_in_agreement() {
    // 50 chained steps: f32 drift must stay bounded and spike decisions
    // aligned (the two backends run the same f32 ops).
    require_artifacts!();
    let handle = service();
    let params = NeuronParams::default();
    let mut native = random_pop(256, 7);
    let mut xla = native.clone();
    for step in 0..50 {
        // Shared noise for both backends.
        let mut rng = Rng::new(1000 + step);
        for x in native.noise.iter_mut() {
            *x = rng.normal_ms(5.0, 1.0) as f32;
        }
        xla.noise.copy_from_slice(&native.noise);

        let out = handle
            .neuron_update(NeuronInputs {
                v: xla.v.clone(),
                u: xla.u.clone(),
                ca: xla.ca.clone(),
                z_ax: xla.z_ax.clone(),
                z_de: xla.z_den_exc.clone(),
                z_di: xla.z_den_inh.clone(),
                i_syn: xla.i_syn.clone(),
                noise: xla.noise.clone(),
                params: params.to_vec(),
            })
            .unwrap();
        xla.v = out.v;
        xla.u = out.u;
        xla.ca = out.ca;
        xla.z_ax = out.z_ax;
        xla.z_den_exc = out.z_de;
        xla.z_den_inh = out.z_di;
        for (i, &f) in out.fired.iter().enumerate() {
            xla.fired[i] = f > 0.5;
        }
        izhikevich::step(&mut native, &params);
        let agree =
            native.fired.iter().zip(&xla.fired).filter(|(a, b)| a == b).count();
        assert!(
            agree as f64 / native.fired.len() as f64 > 0.99,
            "step {step}: spike agreement dropped to {agree}/256"
        );
    }
    assert_close("ca after 50 steps", &native.ca, &xla.ca, 1e-2);
    handle.shutdown();
}

#[test]
fn xla_gauss_probs_matches_native_kernel() {
    require_artifacts!();
    let handle = service();
    let mut rng = Rng::new(11);
    let n = 777; // padded to 1024
    let tx: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 1000.0) as f32).collect();
    let ty: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 1000.0) as f32).collect();
    let tz: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 1000.0) as f32).collect();
    let vac: Vec<f32> = (0..n).map(|_| rng.next_below(4) as f32).collect();
    let src = [500.0f32, 500.0, 500.0];
    let sigma = 750.0f32;
    let got = handle.gauss_probs(src, sigma, tx.clone(), ty.clone(), tz.clone(), vac.clone()).unwrap();
    assert_eq!(got.len(), n);
    for i in 0..n {
        let d2 = (tx[i] - src[0]).powi(2) + (ty[i] - src[1]).powi(2) + (tz[i] - src[2]).powi(2);
        let want = ilmi::barnes_hut::kernel_weight(vac[i], d2 as f64, sigma as f64) as f32;
        let scale = want.abs().max(1e-6);
        assert!((got[i] - want).abs() <= 1e-4 * scale + 1e-7, "probs[{i}]: {} vs {want}", got[i]);
    }
    handle.shutdown();
}

#[test]
fn full_simulation_on_xla_backend_matches_native() {
    // The end-to-end cross-check: same config, same seeds, two backends.
    // Spike decisions are bit-aligned per step (verified above), so the
    // network trajectories should match statistically.
    require_artifacts!();
    let cfg_native = SimConfig {
        ranks: 2,
        neurons_per_rank: 48,
        steps: 300,
        plasticity_interval: 100,
        delta: 100,
        ..SimConfig::default()
    };
    let mut cfg_xla = cfg_native.clone();
    cfg_xla.backend = Backend::Xla;

    let native = run_simulation(&cfg_native).unwrap();
    let handle = service();
    let xla = run_simulation_with_xla(&cfg_xla, Some(handle.clone())).unwrap();
    handle.shutdown();

    let (sn, sx) = (native.total_synapses() as f64, xla.total_synapses() as f64);
    assert!(sx > 0.0);
    assert!(
        (sn - sx).abs() / sn.max(sx) < 0.2,
        "backends diverge: native {sn} synapses vs xla {sx}"
    );
    assert!(
        (native.mean_calcium() - xla.mean_calcium()).abs() < 0.05,
        "calcium: native {:.3} vs xla {:.3}",
        native.mean_calcium(),
        xla.mean_calcium()
    );
}
