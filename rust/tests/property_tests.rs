//! Property-based tests over the simulator's core invariants
//! (mini-harness in `ilmi::testing`; `proptest` is not in the offline
//! crate set — see DESIGN.md §6).

use ilmi::barnes_hut::select::{select_local, SelectParams, SelectScratch};
use ilmi::barnes_hut::{accept_proposals, Proposal};
use ilmi::comm::run_ranks;
use ilmi::config::SimConfig;
use ilmi::neuron::Population;
use ilmi::octree::{DomainDecomposition, ElementKind, Octree, NO_NEURON};
use ilmi::plasticity::{run_deletion_phase, SynapseStore};
use ilmi::testing::comm_props::{
    check_all_to_all_routes, check_rma_oob_fails_cleanly, check_wire_pins,
};
use ilmi::testing::forall;
use ilmi::util::{morton, Rng, Vec3};

fn random_positions(rng: &mut Rng, n: usize, size: f64) -> Vec<Vec3> {
    (0..n)
        .map(|_| {
            Vec3::new(rng.uniform(0.0, size), rng.uniform(0.0, size), rng.uniform(0.0, size))
        })
        .collect()
}

#[test]
fn prop_morton_roundtrip() {
    forall(
        "morton encode/decode roundtrip",
        500,
        |rng| {
            (
                rng.next_u64() & 0x1F_FFFF,
                rng.next_u64() & 0x1F_FFFF,
                rng.next_u64() & 0x1F_FFFF,
            )
        },
        |&(x, y, z)| {
            if morton::decode(morton::encode(x, y, z)) == (x, y, z) {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        },
    );
}

#[test]
fn prop_octree_aggregation_conserves_vacancy() {
    forall(
        "octree root vacancy == sum of leaf vacancies",
        40,
        |rng| {
            let n = 1 + rng.next_below(200);
            let positions = random_positions(rng, n, 100.0);
            let vac_exc: Vec<f32> = (0..n).map(|_| rng.next_below(4) as f32).collect();
            let vac_inh: Vec<f32> = (0..n).map(|_| rng.next_below(3) as f32).collect();
            (positions, vac_exc, vac_inh)
        },
        |(positions, vac_exc, vac_inh)| {
            let decomp = DomainDecomposition::new(1, 100.0);
            let mut tree = Octree::build(&decomp, 0, 0, positions);
            tree.reset_and_set_leaves(0, vac_exc, vac_inh);
            tree.aggregate_local();
            tree.aggregate_upper();
            tree.normalize();
            let root = &tree.nodes[0];
            let se: f32 = vac_exc.iter().sum();
            let si: f32 = vac_inh.iter().sum();
            if (root.vac_exc - se).abs() > 1e-2 * se.max(1.0) {
                return Err(format!("exc: {} vs {}", root.vac_exc, se));
            }
            if (root.vac_inh - si).abs() > 1e-2 * si.max(1.0) {
                return Err(format!("inh: {} vs {}", root.vac_inh, si));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_octree_every_neuron_in_one_leaf() {
    forall(
        "octree stores each neuron exactly once",
        30,
        |rng| {
            let n = 1 + rng.next_below(300);
            random_positions(rng, n, 50.0)
        },
        |positions| {
            let decomp = DomainDecomposition::new(1, 50.0);
            let tree = Octree::build(&decomp, 0, 0, positions);
            let mut seen = vec![0usize; positions.len()];
            for node in &tree.nodes {
                if node.neuron != NO_NEURON {
                    seen[node.neuron as usize] += 1;
                }
            }
            if seen.iter().all(|&c| c == 1) {
                Ok(())
            } else {
                Err(format!("leaf counts: {seen:?}"))
            }
        },
    );
}

#[test]
fn prop_select_local_respects_vacancy_and_exclusion() {
    forall(
        "select_local returns only admissible targets",
        30,
        |rng| {
            let n = 2 + rng.next_below(60);
            let positions = random_positions(rng, n, 100.0);
            let vac: Vec<f32> = (0..n).map(|_| rng.next_below(3) as f32).collect();
            let exclude = rng.next_below(n) as u64;
            let theta = rng.uniform(0.0, 0.6);
            (positions, vac, exclude, theta)
        },
        |(positions, vac, exclude, theta)| {
            let decomp = DomainDecomposition::new(1, 100.0);
            let mut tree = Octree::build(&decomp, 0, 0, positions);
            tree.reset_and_set_leaves(0, vac, vac);
            tree.aggregate_local();
            tree.aggregate_upper();
            tree.normalize();
            let params = SelectParams {
                theta: *theta,
                sigma: 500.0,
                exclude: *exclude,
                kind: ElementKind::Excitatory,
            };
            let mut scratch = SelectScratch::default();
            let mut rng2 = Rng::new(exclude * 31 + positions.len() as u64);
            for _ in 0..20 {
                match select_local(
                    &tree,
                    tree.root(),
                    &positions[*exclude as usize],
                    &params,
                    &mut scratch,
                    &mut rng2,
                ) {
                    Some(id) => {
                        if id == *exclude {
                            return Err("selected the excluded source".into());
                        }
                        if vac[id as usize] <= 0.0 {
                            return Err(format!("selected zero-vacancy neuron {id}"));
                        }
                    }
                    None => {
                        // Legal only if no other neuron has vacancy.
                        let any = vac
                            .iter()
                            .enumerate()
                            .any(|(i, &v)| i as u64 != *exclude && v > 0.0);
                        if any {
                            return Err("returned None despite candidates".into());
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_synapse_store_random_ops_keep_invariants() {
    forall(
        "synapse store counters match edge lists under random ops",
        50,
        |rng| {
            let ops: Vec<u8> = (0..200).map(|_| rng.next_below(5) as u8).collect();
            (rng.next_u64(), ops)
        },
        |(seed, ops)| {
            let mut rng = Rng::new(*seed);
            let n = 8;
            let mut store = SynapseStore::new(n, 8);
            for &op in ops {
                let local = rng.next_below(n);
                match op {
                    0 => store.add_out(local, rng.next_below(64) as u64),
                    1 => store.add_in(local, rng.next_below(64) as u64, rng.bernoulli(0.5)),
                    2 => {
                        store.remove_random_out(local, &mut rng);
                    }
                    3 => {
                        store.remove_random_in(local, ElementKind::Excitatory, &mut rng);
                    }
                    _ => {
                        store.remove_random_in(local, ElementKind::Inhibitory, &mut rng);
                    }
                }
            }
            store.check_invariants()
        },
    );
}

#[test]
fn prop_acceptance_never_exceeds_capacity() {
    forall(
        "accepted proposals <= vacant dendritic elements",
        40,
        |rng| {
            let n_neurons = 1 + rng.next_below(6);
            let n_props = rng.next_below(40);
            let caps: Vec<f32> = (0..n_neurons).map(|_| rng.next_below(5) as f32).collect();
            let props: Vec<(usize, bool)> = (0..n_props)
                .map(|_| (rng.next_below(n_neurons), rng.bernoulli(0.7)))
                .collect();
            (rng.next_u64(), caps, props)
        },
        |(seed, caps, props)| {
            let cfg = SimConfig { neurons_per_rank: caps.len(), ..SimConfig::default() };
            let mut rng = Rng::new(*seed);
            let mut pop =
                Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(10.0), &mut rng);
            for (i, &c) in caps.iter().enumerate() {
                pop.z_den_exc[i] = c;
                pop.z_den_inh[i] = c;
            }
            let mut store = SynapseStore::new(caps.len(), caps.len().max(1) as u64);
            let proposals: Vec<Proposal> = props
                .iter()
                .enumerate()
                .map(|(k, &(t, exc))| Proposal {
                    source: 1000 + k as u64,
                    source_exc: exc,
                    target_local: t,
                })
                .collect();
            let ok = accept_proposals(&pop, &mut store, &proposals, &mut rng);
            store.check_invariants()?;
            for (i, &c) in caps.iter().enumerate() {
                if store.connected_den_exc[i] as f32 > c {
                    return Err(format!("neuron {i} exc over capacity"));
                }
                if store.connected_den_inh[i] as f32 > c {
                    return Err(format!("neuron {i} inh over capacity"));
                }
            }
            // Everything accepted must be recorded.
            let accepted = ok.iter().filter(|&&s| s).count();
            if accepted != store.total_in() {
                return Err("accepted count != stored in-edges".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deletion_restores_element_consistency() {
    forall(
        "after deletion, connected <= floor(z) on every side",
        12,
        |rng| rng.next_u64(),
        |&seed| {
            // Two ranks with random synapses between 4 neurons each, then
            // random element counts; deletion must restore consistency
            // and keep both sides of every synapse in agreement.
            let results = run_ranks(2, move |comm| {
                let rank = comm.rank();
                let cfg = SimConfig { neurons_per_rank: 4, ..SimConfig::default() };
                let mut rng = Rng::new(seed ^ rank as u64);
                let mut pop =
                    Population::init(&cfg, rank, Vec3::ZERO, Vec3::splat(10.0), &mut rng);
                let mut store = SynapseStore::new(4, 4);
                // Build a deterministic, globally consistent edge set:
                // neuron (r, i) -> neuron (1-r, i) for all i (exc).
                for i in 0..4 {
                    let other = ((1 - rank) * 4 + i) as u64;
                    store.add_out(i, other);
                    store.add_in(i, ((1 - rank) * 4 + i) as u64, true);
                }
                // Random element counts in [0, 2].
                for i in 0..4 {
                    pop.z_ax[i] = rng.next_below(3) as f32;
                    pop.z_den_exc[i] = rng.next_below(3) as f32;
                    pop.z_den_inh[i] = 2.0;
                }
                run_deletion_phase(&comm, &pop, &mut store, &mut rng, |id| {
                    (id / 4) as usize
                });
                store.check_invariants().unwrap();
                for i in 0..4 {
                    assert!(
                        store.connected_ax[i] as i64 <= pop.z_ax[i].floor() as i64,
                        "rank {rank} neuron {i} axon over"
                    );
                    assert!(
                        store.connected_den_exc[i] as i64
                            <= pop.z_den_exc[i].floor() as i64
                    );
                }
                (store.total_out(), store.total_in())
            });
            let out: usize = results.iter().map(|r| r.0).sum();
            let inn: usize = results.iter().map(|r| r.1).sum();
            if out != inn {
                return Err(format!("dangling edges: out {out} != in {inn}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_all_to_all_conserves_bytes() {
    forall(
        "sum of bytes sent == sum of bytes received",
        10,
        |rng| rng.next_u64(),
        |&seed| {
            let results = run_ranks(4, move |comm| {
                let mut rng = Rng::new(seed ^ (comm.rank() as u64) << 8);
                for _ in 0..5 {
                    let sends: Vec<Vec<u8>> =
                        (0..4).map(|_| vec![0u8; rng.next_below(100)]).collect();
                    comm.all_to_all(sends);
                }
                comm.counters().snapshot()
            });
            let sent: u64 = results.iter().map(|s| s.bytes_sent).sum();
            let recv: u64 = results.iter().map(|s| s.bytes_recv).sum();
            if sent != recv {
                return Err(format!("sent {sent} != recv {recv}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_comm_all_to_all_routes_any_raggedness() {
    // Backend-generic Comm semantics (DESIGN.md §11): ragged, empty and
    // zero-length send lists all route permutation-correctly and count
    // identically. The shared check bodies live in
    // `ilmi::testing::comm_props` so the cross-backend differential
    // suite runs the very same assertions over `SocketComm`.
    forall(
        "all_to_all routes ragged payloads and counts them",
        6,
        |rng| rng.next_u64(),
        |&seed| {
            run_ranks(3, move |comm| check_all_to_all_routes(&comm, seed));
            Ok(())
        },
    );
}

#[cfg(unix)]
#[test]
fn prop_comm_all_to_all_routes_over_sockets() {
    forall(
        "socket all_to_all routes ragged payloads and counts them",
        3,
        |rng| rng.next_u64(),
        |&seed| {
            ilmi::comm::socket_ranks(3, move |comm| check_all_to_all_routes(&comm, seed));
            Ok(())
        },
    );
}

#[test]
fn prop_comm_rma_oob_fails_cleanly() {
    run_ranks(2, |comm| check_rma_oob_fails_cleanly(&comm));
}

#[test]
fn prop_comm_wire_sizes_are_pinned() {
    check_wire_pins();
}

#[test]
fn prop_config_kv_roundtrip() {
    forall(
        "numeric config keys accept what they print",
        50,
        |rng| {
            (
                1 + rng.next_below(64),
                1 + rng.next_below(4096),
                (rng.next_below(40) as f64) / 100.0,
            )
        },
        |&(ranks, npr, theta)| {
            let mut cfg = SimConfig::default();
            cfg.apply_kv("topology.ranks", &ranks.to_string())?;
            cfg.apply_kv("topology.neurons_per_rank", &npr.to_string())?;
            cfg.apply_kv("algorithms.theta", &theta.to_string())?;
            cfg.validate()?;
            if cfg.ranks != ranks || cfg.neurons_per_rank != npr {
                return Err("values not applied".into());
            }
            Ok(())
        },
    );
}
