//! Cross-backend differential suite: the thread and socket
//! communicators must be interchangeable transports (DESIGN.md §11).
//!
//! The same seeded configuration is run once over `run_ranks`
//! (`ThreadComm`) and once over `socket_ranks` (`SocketComm` — real UDS
//! frames, RMA server threads, hosted on threads of this process), and
//! everything except wall-clock timing must be bit-identical per rank:
//! the ILMISNAP capture bytes (the full dynamics state, RNG streams
//! included), the deterministic fields of the encoded `RankReport`, and
//! every rank's `CounterSnapshot`. Both spike algorithms are covered,
//! plus a skewed load-balancing run (migration collectives) and, via
//! `zz_socket_child`, the end-to-end process-per-rank launcher.
//!
//! Also here: the backend-generic `Comm` property checks
//! (`ilmi::testing::comm_props`) run over both transports, and the
//! fault-injection regressions — a dead peer poisons survivors instead
//! of deadlocking them, and truncated frames are checked-decode errors.

#![cfg(unix)]

use std::time::{Duration, Instant};

use ilmi::bench::{AlgGen, Regime, RunSettings, Scenario};
use ilmi::comm::proc::{self, Entry, LaunchSpec};
use ilmi::comm::{decode_frame, run_ranks, socket_ranks, Comm, CounterSnapshot, SocketComm};
use ilmi::config::{CommBackend, KernelKind, SimConfig};
use ilmi::coordinator::{run_simulation, RankState, SOCKET_ENTRIES};
use ilmi::metrics::RankReport;
use ilmi::testing::comm_props::{check_all_to_all_routes, check_rma_oob_fails_cleanly};

// -- differential harness ------------------------------------------------

/// Everything one rank produces that must be backend-independent.
type Digest = (Vec<u8>, Vec<u8>, Vec<CounterSnapshot>);

/// Encode a report with its wall-clock-derived fields zeroed; all
/// remaining bytes are functions of the seeded trajectory alone.
fn deterministic_bytes(mut r: RankReport) -> Vec<u8> {
    r.phase_seconds = Default::default();
    r.formation.compute_nanos = 0;
    r.formation.exchange_nanos = 0;
    for s in &mut r.trace {
        s.ts_micros = 0.0;
        s.phase_seconds = Default::default();
        s.cost.nanos = 0;
    }
    // Comm-latency histogram TOTALS are deterministic call counts, but
    // which bucket each call lands in is wall-clock; collapse the
    // spread, keep the totals comparable.
    r.comm_hists = r.comm_hists.collapse();
    r.encode()
}

/// The per-rank simulation body, generic over the transport: run every
/// step, then capture the ILMISNAP section, the quiesced per-rank
/// counter snapshots, and the deterministic report bytes.
fn rank_digest(cfg: &SimConfig, comm: &impl Comm) -> Digest {
    let mut state = RankState::init(cfg, comm);
    for step in 0..cfg.steps {
        state.step(cfg, comm, step).expect("step failed");
    }
    // The capture embeds FormationStats, whose nanos are wall-clock;
    // zero them on the live state so the section bytes are pure state.
    state.formation.compute_nanos = 0;
    state.formation.exchange_nanos = 0;
    let section = state.capture(comm);
    comm.barrier(); // quiesce: every rank's counters are final
    let all = comm.all_counters();
    (section, deterministic_bytes(state.into_report(comm)), all)
}

fn assert_backends_agree(cfg: &SimConfig, label: &str) {
    let threads: Vec<Digest> = run_ranks(cfg.ranks, |comm| rank_digest(cfg, &comm));
    let sockets: Vec<Digest> = socket_ranks(cfg.ranks, |comm| rank_digest(cfg, &comm));
    for (rank, (t, s)) in threads.iter().zip(&sockets).enumerate() {
        assert_eq!(t.0, s.0, "{label}: rank {rank} ILMISNAP section bytes differ");
        assert_eq!(t.1, s.1, "{label}: rank {rank} report bytes differ");
        assert_eq!(t.2, s.2, "{label}: rank {rank} counter snapshots differ");
    }
}

fn smoke_settings() -> RunSettings {
    RunSettings { steps: 60, plasticity_interval: 30, warmup: 0, reps: 1, seed: 42 }
}

fn smoke_scenario(alg: AlgGen) -> Scenario {
    Scenario {
        alg,
        ranks: 2,
        neurons_per_rank: 16,
        delta: 30,
        regime: Regime::Active,
        skew: false,
        kernel: KernelKind::Scalar,
    }
}

#[test]
fn new_algorithms_are_bit_identical_across_backends() {
    let mut cfg = smoke_scenario(AlgGen::New).config(&smoke_settings());
    // Tracing on: epoch samples must survive the socket path too.
    cfg.trace_every = 30;
    cfg.trace_capacity = 8;
    assert_backends_agree(&cfg, "new/new smoke");
}

#[test]
fn old_algorithms_are_bit_identical_across_backends() {
    // The old generation downloads octree nodes over RMA: this is the
    // request/reply window path on the socket transport.
    let cfg = smoke_scenario(AlgGen::Old).config(&smoke_settings());
    assert_backends_agree(&cfg, "old/old smoke");
}

#[test]
fn balanced_skewed_run_is_bit_identical_across_backends() {
    // Skewed start + load balancing: plasticity epochs plus migration
    // all_to_alls, the heaviest collective traffic in the repo.
    let settings =
        RunSettings { steps: 150, plasticity_interval: 50, warmup: 0, reps: 1, seed: 42 };
    let cfg = Scenario {
        alg: AlgGen::New,
        ranks: 2,
        neurons_per_rank: 32,
        delta: 50,
        regime: Regime::Active,
        skew: true,
        kernel: KernelKind::Scalar,
    }
    .config(&settings);
    assert_backends_agree(&cfg, "skewed balance run");
}

// -- Comm property checks, generic over backend --------------------------

#[test]
fn all_to_all_property_holds_on_both_backends() {
    for seed in [0xA11u64, 0xB22, 0xC33] {
        run_ranks(3, |comm| check_all_to_all_routes(&comm, seed));
        socket_ranks(3, |comm| check_all_to_all_routes(&comm, seed));
    }
}

#[test]
fn rma_failures_are_clean_on_both_backends() {
    run_ranks(2, |comm| check_rma_oob_fails_cleanly(&comm));
    socket_ranks(2, |comm| check_rma_oob_fails_cleanly(&comm));
}

// -- fault injection ----------------------------------------------------

#[test]
fn dead_peer_poisons_survivor_instead_of_deadlocking() {
    let start = Instant::now();
    let err = std::panic::catch_unwind(|| {
        socket_ranks(2, |comm| {
            if comm.rank() == 0 {
                return; // drop the comm: streams close, peer sees EOF
            }
            // Give rank 0 a moment to leave, then enter a collective it
            // will never join.
            std::thread::sleep(Duration::from_millis(50));
            let _ = comm.all_to_all(vec![vec![1u8; 8], vec![1u8; 8]]);
        })
    })
    .expect_err("the survivor must panic, not deadlock");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "non-string panic".into());
    assert!(msg.contains("unreachable"), "diagnostic names the failure: {msg}");
    assert!(msg.contains("poisoned"), "communicator must be poisoned: {msg}");
    // Bounded by the transport's read timeout, not a deadlock.
    assert!(start.elapsed() < Duration::from_secs(25), "took {:?}", start.elapsed());
}

#[test]
fn truncated_frames_are_rejected_not_misparsed() {
    let frame = ilmi::comm::encode_frame(2, &[7u8; 42]);
    for cut in 0..frame.len() {
        let err = decode_frame(&frame[..cut]).expect_err("prefix must not parse");
        assert!(err.contains("truncated"), "cut {cut}: {err}");
    }
    assert_eq!(decode_frame(&frame).unwrap(), (2, vec![7u8; 42]));
}

// -- process-per-rank launcher, end to end -------------------------------

/// Point `proc::run_entry` children at this binary's `zz_socket_child`
/// hook (the launcher re-execs the current executable, which under
/// libtest is this test binary).
fn set_child_hook() {
    std::env::set_var(proc::ENV_CHILD_ARGS, "zz_socket_child --exact");
}

fn die_mid_collective(comm: &SocketComm, _args: &[u8]) -> Result<Vec<u8>, String> {
    comm.barrier(); // everyone joined; the fleet is healthy so far
    if comm.rank() == 0 {
        std::process::exit(2); // die without reporting
    }
    let sends = (0..comm.size()).map(|_| vec![0u8; 64]).collect();
    let _ = comm.all_to_all(sends); // panics: rank 0 never joins
    Ok(Vec::new())
}

fn echo_entry(comm: &SocketComm, args: &[u8]) -> Result<Vec<u8>, String> {
    let mut out = args.to_vec();
    out.push(comm.rank() as u8);
    Ok(out)
}

fn test_entries() -> Vec<(&'static str, Entry)> {
    let mut entries = SOCKET_ENTRIES.to_vec();
    entries.push(("die_mid_collective", die_mid_collective as Entry));
    entries.push(("echo", echo_entry as Entry));
    entries
}

/// Child-side hook: every rank process the launcher spawns from this
/// binary runs exactly this test (`--exact`), which dispatches into the
/// entry registry and exits. A normal suite run (no `ILMI_COMM_ENTRY`
/// in the environment) falls straight through.
#[test]
fn zz_socket_child() {
    proc::maybe_run_child(&test_entries());
}

#[test]
fn launcher_runs_entries_and_collects_results_in_rank_order() {
    set_child_hook();
    let spec = LaunchSpec {
        entry: "echo",
        ranks: 3,
        args: b"hi",
        timeout: Duration::from_secs(60),
        env: &[],
        watchdog_misses: 0,
        on_beat: None,
    };
    let results = proc::run_entry(&spec).expect("launch failed");
    for (rank, bytes) in results.iter().enumerate() {
        assert_eq!(bytes, &[b'h', b'i', rank as u8], "rank {rank}");
    }
}

/// Count THIS process's launcher rendezvous directories currently on
/// disk (`ilmi-pc<pid>-<seq>`; the pid scoping excludes other test
/// binaries running concurrently).
fn rendezvous_dirs() -> usize {
    let prefix = format!("ilmi-pc{}-", std::process::id());
    std::fs::read_dir(std::env::temp_dir())
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().starts_with(&prefix))
                .count()
        })
        .unwrap_or(0)
}

#[test]
fn launcher_cleans_rendezvous_dirs_on_success_and_failure() {
    set_child_hook();
    // Success path: echo fleet comes and goes without leaving a dir.
    // Other tests in this binary launch fleets concurrently, so compare
    // against a baseline taken right before rather than asserting zero.
    let spec = LaunchSpec {
        entry: "echo",
        ranks: 2,
        args: b"ok",
        timeout: Duration::from_secs(60),
        env: &[],
        watchdog_misses: 0,
        on_beat: None,
    };
    proc::run_entry(&spec).expect("launch failed");
    // Failure path: a dying fleet must not leak its dir either (the
    // guard removes it even when run_entry returns Err).
    let spec = LaunchSpec {
        entry: "die_mid_collective",
        ranks: 2,
        args: &[],
        timeout: Duration::from_secs(20),
        env: &[],
        watchdog_misses: 0,
        on_beat: None,
    };
    proc::run_entry(&spec).expect_err("a dead rank must fail the launch");
    // Both fleets above are fully reaped by the time run_entry returns,
    // so any ilmi-pc-* dirs still present belong to fleets of OTHER
    // concurrently-running tests — bounded by this binary's own test
    // thread count, while a leak from the two launches above would
    // accumulate. Run the pair again and require no growth.
    let before = rendezvous_dirs();
    for _ in 0..2 {
        let spec = LaunchSpec {
            entry: "echo",
            ranks: 2,
            args: b"ok",
            timeout: Duration::from_secs(60),
            env: &[],
            watchdog_misses: 0,
            on_beat: None,
        };
        proc::run_entry(&spec).expect("launch failed");
    }
    assert!(
        rendezvous_dirs() <= before + 1,
        "rendezvous dirs accumulated: {} then {}",
        before,
        rendezvous_dirs()
    );
}

#[test]
fn launcher_surfaces_a_dead_rank_as_an_error_not_a_hang() {
    set_child_hook();
    let start = Instant::now();
    let spec = LaunchSpec {
        entry: "die_mid_collective",
        ranks: 2,
        args: &[],
        timeout: Duration::from_secs(20),
        env: &[],
        watchdog_misses: 0,
        on_beat: None,
    };
    let err = proc::run_entry(&spec).expect_err("a dead rank must fail the launch");
    // Either failure order is legitimate: the survivor's poisoned-panic
    // report, or the launcher noticing rank 0 exited without reporting.
    assert!(
        err.contains("poisoned") || err.contains("before reporting"),
        "diagnostic: {err}"
    );
    assert!(start.elapsed() < Duration::from_secs(60), "took {:?}", start.elapsed());
}

#[test]
fn simulate_over_processes_matches_thread_backend() {
    set_child_hook();
    let mut cfg = smoke_scenario(AlgGen::New).config(&smoke_settings());
    let thread_report = run_simulation(&cfg).expect("thread run");
    cfg.comm_backend = CommBackend::Socket;
    let socket_report = run_simulation(&cfg).expect("socket run");
    assert_eq!(socket_report.ranks.len(), thread_report.ranks.len());
    for (t, s) in thread_report.ranks.iter().zip(&socket_report.ranks) {
        assert_eq!(
            deterministic_bytes(t.clone()),
            deterministic_bytes(s.clone()),
            "rank {}: process-per-rank run diverged from the thread run",
            t.rank
        );
    }
}
