//! Fault-tolerance suite: deterministic fault injection + supervised
//! checkpoint-restart recovery (DESIGN.md §13).
//!
//! The headline invariant pinned here: a socket fleet whose rank is
//! KILLED mid-run (and whose newest checkpoint may additionally be
//! CORRUPTED) recovers under the supervisor and finishes with a final
//! snapshot that is byte-for-byte identical to an uninterrupted run's —
//! for both spike-algorithm generations. Recovery is allowed to cost
//! wall time, never trajectory.
//!
//! Also here: the supervisor's give-up path — when `max_recoveries` is
//! exhausted it returns an error promptly (no hang), with every rank
//! process reaped and no rendezvous directory left behind — and the
//! telemetry plane (DESIGN.md §14): a rank that HANGS (stalls, never
//! dies) starves the heartbeat stream, trips the supervisor's watchdog
//! well before any transport read timeout, and recovers through the
//! same checkpoint-restart loop bit-identically; and telemetry itself
//! is pure observation — heartbeats on or off, the final snapshot
//! bytes are identical for both spike-algorithm generations.

#![cfg(unix)]

use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use ilmi::bench::{AlgGen, Regime, RunSettings, Scenario};
use ilmi::comm::proc;
use ilmi::config::{CommBackend, KernelKind, SimConfig};
use ilmi::coordinator::{run_simulation, SOCKET_ENTRIES};
use ilmi::snapshot::snapshot_file_name;

/// Each test launches a 2-process fleet (several times, with kills);
/// running them concurrently would oversubscribe CI and turn launch
/// timeouts flaky, so the suite serializes itself.
static SERIAL: Mutex<()> = Mutex::new(());

/// Child-side hook: rank processes spawned from this binary re-exec it
/// with `--exact zz_socket_child`, which dispatches into the standard
/// entry registry and exits. A normal suite run falls straight through.
#[test]
fn zz_socket_child() {
    proc::maybe_run_child(SOCKET_ENTRIES);
}

fn set_child_hook() {
    std::env::set_var(proc::ENV_CHILD_ARGS, "zz_socket_child --exact");
}

fn fresh_dir(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ilmi_ft_{label}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A 2-rank socket run with checkpoints at steps 50/100/150 and the
/// supervisor armed. 150 steps x 16 neurons keeps one fleet launch
/// comfortably inside the launch timeout even on loaded CI.
fn supervised_cfg(alg: AlgGen, dir: &std::path::Path) -> SimConfig {
    let settings =
        RunSettings { steps: 150, plasticity_interval: 50, warmup: 0, reps: 1, seed: 42 };
    let mut cfg = Scenario {
        alg,
        ranks: 2,
        neurons_per_rank: 16,
        delta: 50,
        regime: Regime::Active,
        skew: false,
        kernel: KernelKind::Scalar,
    }
    .config(&settings);
    cfg.comm_backend = CommBackend::Socket;
    cfg.checkpoint_every = 50;
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg.max_recoveries = 2;
    cfg
}

/// Run clean, capture the final snapshot's bytes, WIPE the directory,
/// rerun with `fault_plan` injected into the SAME directory (same path
/// ⇒ same embedded config INI ⇒ byte-comparable files), and return
/// (clean final bytes, faulted final bytes, faulted report).
fn clean_vs_faulted(
    alg: AlgGen,
    label: &str,
    fault_plan: &str,
) -> (Vec<u8>, Vec<u8>, ilmi::metrics::SimReport) {
    let dir = fresh_dir(label);
    let cfg = supervised_cfg(alg, &dir);
    let clean = run_simulation(&cfg).expect("clean supervised run");
    assert_eq!(clean.recoveries, 0, "nothing failed, nothing to recover");
    let final_name = snapshot_file_name(150);
    let clean_bytes = std::fs::read(dir.join(&final_name)).expect("clean final snapshot");
    std::fs::remove_dir_all(&dir).unwrap();
    std::fs::create_dir_all(&dir).unwrap();

    let mut faulted = cfg;
    faulted.fault_plan = fault_plan.to_string();
    let report = run_simulation(&faulted).expect("faulted run must recover");
    let faulted_bytes = std::fs::read(dir.join(&final_name)).expect("recovered final snapshot");
    let _ = std::fs::remove_dir_all(&dir);
    (clean_bytes, faulted_bytes, report)
}

#[test]
fn killed_rank_recovers_bit_identically_new_algorithms() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    set_child_hook();
    let (clean, faulted, report) =
        clean_vs_faulted(AlgGen::New, "kill_new", "kill:rank=1,step=120");
    assert_eq!(report.recoveries, 1, "exactly one supervised relaunch");
    // Kill at 120, newest checkpoint at 100: no checkpoint evidence of
    // steps past 100, so the proven-lost count is 0 (a lower bound).
    assert_eq!(report.lost_steps, 0);
    for r in &report.ranks {
        assert_eq!(r.recoveries, 1, "rank {} carries the recovery count", r.rank);
    }
    assert_eq!(clean, faulted, "recovered final snapshot must be byte-identical");
}

#[test]
fn killed_rank_recovers_bit_identically_old_algorithms() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    set_child_hook();
    // The old generation exercises the RMA window path during recovery.
    let (clean, faulted, report) =
        clean_vs_faulted(AlgGen::Old, "kill_old", "kill:rank=1,step=120");
    assert_eq!(report.recoveries, 1);
    assert_eq!(clean, faulted, "recovered final snapshot must be byte-identical");
}

#[test]
fn corrupt_newest_checkpoint_falls_back_to_older_ring_entry() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    set_child_hook();
    // The step-100 checkpoint is written truncated (fails its content
    // checksum), then rank 1 dies at 120: the scan must reject the
    // corrupt newest file and resume from step 50 instead — replaying
    // 50 provably-lost steps — and still finish bit-identically.
    let (clean, faulted, report) = clean_vs_faulted(
        AlgGen::New,
        "corrupt_newest",
        "ckpt_corrupt:step=100;kill:rank=1,step=120",
    );
    assert_eq!(report.recoveries, 1);
    assert_eq!(report.lost_steps, 50, "step-100 evidence minus step-50 resume point");
    assert!(report.recovery_seconds > 0.0);
    assert_eq!(clean, faulted, "recovered final snapshot must be byte-identical");
}

/// Like `clean_vs_faulted`, but the fault HANGS a rank instead of
/// killing it: the faulted run arms telemetry (beats every 5 steps, a
/// 3-miss watchdog budget) so the supervisor detects the silence and
/// recovers. The clean run stays telemetry-free, so the byte comparison
/// additionally pins telemetry purity across the pair. The faulted run
/// is time-bounded WELL below both the hour-long stall and the socket
/// transport's read timeout (≥60s): only the watchdog path can finish
/// that fast.
fn clean_vs_hung(
    alg: AlgGen,
    label: &str,
    fault_plan: &str,
) -> (Vec<u8>, Vec<u8>, ilmi::metrics::SimReport) {
    let dir = fresh_dir(label);
    let cfg = supervised_cfg(alg, &dir);
    let clean = run_simulation(&cfg).expect("clean supervised run");
    assert_eq!(clean.recoveries, 0, "nothing failed, nothing to recover");
    let final_name = snapshot_file_name(150);
    let clean_bytes = std::fs::read(dir.join(&final_name)).expect("clean final snapshot");
    std::fs::remove_dir_all(&dir).unwrap();
    std::fs::create_dir_all(&dir).unwrap();

    let mut hung = cfg;
    hung.fault_plan = fault_plan.to_string();
    hung.telemetry_every = 5;
    hung.telemetry_watchdog_misses = 3;
    let start = Instant::now();
    let report = run_simulation(&hung).expect("hung run must recover via the watchdog");
    assert!(
        start.elapsed() < Duration::from_secs(45),
        "{label}: recovery took {:?} — watchdog did not fire (a transport read \
         timeout would need >=60s, the injected stall 3600s)",
        start.elapsed()
    );
    let hung_bytes = std::fs::read(dir.join(&final_name)).expect("recovered final snapshot");
    let _ = std::fs::remove_dir_all(&dir);
    (clean_bytes, hung_bytes, report)
}

#[test]
fn hung_rank_trips_the_watchdog_and_recovers_old_algorithms() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    set_child_hook();
    // rank 0's first RMA reply at/after step 120 stalls for an hour —
    // the Barnes-Hut window path of the old generation. The requesting
    // rank blocks inside rma_get, beats stop, the watchdog kills the
    // fleet, and the supervisor resumes from the step-100 checkpoint
    // (attempt 1 re-runs fault-free: the spec defaults to attempt=0).
    let (clean, hung, report) = clean_vs_hung(
        AlgGen::Old,
        "stall_old",
        "rma_stall:rank=0,nth=1,ms=3600000,step=120",
    );
    assert_eq!(report.recoveries, 1, "exactly one watchdog-driven relaunch");
    assert_eq!(clean, hung, "recovered final snapshot must be byte-identical");
}

#[test]
fn hung_rank_trips_the_watchdog_and_recovers_new_algorithms() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    set_child_hook();
    // The new generation never touches RMA; stall rank 1's first data
    // frame at/after step 120 instead (collective traffic path).
    let (clean, hung, report) = clean_vs_hung(
        AlgGen::New,
        "stall_new",
        "frame_delay:rank=1,nth=1,ms=3600000,step=120",
    );
    assert_eq!(report.recoveries, 1, "exactly one watchdog-driven relaunch");
    assert_eq!(clean, hung, "recovered final snapshot must be byte-identical");
}

#[test]
fn telemetry_is_pure_observation_for_both_algorithm_generations() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    set_child_hook();
    for (alg, label) in [(AlgGen::New, "pure_new"), (AlgGen::Old, "pure_old")] {
        let dir = fresh_dir(label);
        let cfg = supervised_cfg(alg, &dir);
        run_simulation(&cfg).expect("telemetry-off run");
        let final_name = snapshot_file_name(150);
        let off = std::fs::read(dir.join(&final_name)).expect("final snapshot");
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::create_dir_all(&dir).unwrap();

        // Same run with beats at a deliberately aggressive cadence, the
        // watchdog armed, and status aggregation on: the trajectory —
        // and therefore the snapshot bytes — must not move.
        let status_dir = fresh_dir(&format!("{label}_status"));
        let mut on = cfg;
        on.telemetry_every = 2;
        on.telemetry_watchdog_misses = 3;
        on.status_dir = status_dir.to_string_lossy().into_owned();
        run_simulation(&on).expect("telemetry-on run");
        let with_telemetry = std::fs::read(dir.join(&final_name)).expect("final snapshot");
        assert_eq!(off, with_telemetry, "{label}: telemetry perturbed the trajectory");
        // The supervisor left a terminal status.json behind, and the
        // `ilmi status` renderer accepts it.
        let rendered = ilmi::telemetry::render_status(&status_dir).expect("status.json");
        assert!(rendered.contains("state done"), "{label}: {rendered}");
        assert!(rendered.contains("watchdog armed"), "{label}: {rendered}");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&status_dir);
    }
}

/// Rendezvous dirs of THIS process's launcher (`ilmi-pc<pid>-<seq>`).
fn rendezvous_dirs() -> usize {
    let prefix = format!("ilmi-pc{}-", std::process::id());
    std::fs::read_dir(std::env::temp_dir())
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().starts_with(&prefix))
                .count()
        })
        .unwrap_or(0)
}

#[test]
fn supervisor_gives_up_cleanly_when_recoveries_are_exhausted() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    set_child_hook();
    let dir = fresh_dir("give_up");
    let mut cfg = supervised_cfg(AlgGen::New, &dir);
    // A kill on the first launch AND on the recovery attempt, with only
    // one recovery allowed: the supervisor must recover once, watch the
    // fleet die again, and give up with an error — promptly, with every
    // child reaped and no rendezvous dir left behind.
    cfg.fault_plan = "kill:rank=1,step=120;kill:rank=1,step=120,attempt=1".to_string();
    cfg.max_recoveries = 1;
    let dirs_before = rendezvous_dirs();
    let start = Instant::now();
    let err = run_simulation(&cfg).expect_err("both attempts die; the run must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("giving up"), "diagnostic: {msg}");
    assert!(msg.contains("max_recoveries"), "names the knob to raise: {msg}");
    // Two short fleet launches plus one backoff — nowhere near the
    // per-launch timeout, so a hang would be caught here.
    assert!(start.elapsed() < Duration::from_secs(120), "took {:?}", start.elapsed());
    assert_eq!(rendezvous_dirs(), dirs_before, "rendezvous dirs leaked");
    let _ = std::fs::remove_dir_all(&dir);
}
