//! Integration tests: whole simulations across ranks, old vs new
//! algorithm behaviour, byte accounting, homeostasis.

use ilmi::config::{ConnectivityAlg, SimConfig, SpikeAlg};
use ilmi::coordinator::run_simulation;

fn base_cfg() -> SimConfig {
    SimConfig {
        ranks: 4,
        neurons_per_rank: 64,
        steps: 400,
        plasticity_interval: 100,
        delta: 100,
        ..SimConfig::default()
    }
}

fn with_algs(conn: ConnectivityAlg, spikes: SpikeAlg) -> SimConfig {
    SimConfig { connectivity_alg: conn, spike_alg: spikes, ..base_cfg() }
}

#[test]
fn synapse_bookkeeping_globally_consistent_all_algorithms() {
    for (conn, spikes) in [
        (ConnectivityAlg::NewLocationAware, SpikeAlg::NewFrequency),
        (ConnectivityAlg::OldRma, SpikeAlg::OldIds),
        (ConnectivityAlg::Direct, SpikeAlg::OldIds),
    ] {
        let report = run_simulation(&with_algs(conn, spikes)).unwrap();
        let out: usize = report.ranks.iter().map(|r| r.synapses_out).sum();
        let inn: usize = report.ranks.iter().map(|r| r.synapses_in).sum();
        assert_eq!(out, inn, "{conn:?}/{spikes:?}: axonal vs dendritic mismatch");
        assert!(out > 0, "{conn:?}/{spikes:?}: nothing formed");
    }
}

#[test]
fn new_algorithm_uses_no_rma_old_does() {
    let new = run_simulation(&with_algs(
        ConnectivityAlg::NewLocationAware,
        SpikeAlg::NewFrequency,
    ))
    .unwrap();
    assert_eq!(new.total_bytes_rma(), 0, "location-aware algorithm must never RMA");

    let old =
        run_simulation(&with_algs(ConnectivityAlg::OldRma, SpikeAlg::OldIds)).unwrap();
    assert!(old.total_bytes_rma() > 0, "old algorithm should download octree nodes");
}

#[test]
fn old_and_new_form_similar_connectivity() {
    // The paper's claim (SS IV-A): the location-aware algorithm computes
    // the same distribution, only with different PRNG state — results
    // must agree qualitatively, not bitwise.
    let old =
        run_simulation(&with_algs(ConnectivityAlg::OldRma, SpikeAlg::NewFrequency)).unwrap();
    let new = run_simulation(&with_algs(
        ConnectivityAlg::NewLocationAware,
        SpikeAlg::NewFrequency,
    ))
    .unwrap();
    let (a, b) = (old.total_synapses() as f64, new.total_synapses() as f64);
    let rel = (a - b).abs() / a.max(b);
    assert!(rel < 0.15, "synapse counts diverge: old {a} vs new {b}");
}

#[test]
fn barnes_hut_tracks_direct_solution() {
    // theta -> 0 approaches the direct O(n^2) distribution; even at 0.3
    // the aggregate synapse counts should be close.
    let bh = run_simulation(&with_algs(
        ConnectivityAlg::NewLocationAware,
        SpikeAlg::NewFrequency,
    ))
    .unwrap();
    let direct =
        run_simulation(&with_algs(ConnectivityAlg::Direct, SpikeAlg::NewFrequency)).unwrap();
    let (a, b) = (bh.total_synapses() as f64, direct.total_synapses() as f64);
    let rel = (a - b).abs() / a.max(b);
    assert!(rel < 0.15, "Barnes-Hut {a} vs direct {b}");
}

#[test]
fn frequency_approximation_preserves_calcium_dynamics() {
    // Scaled-down SS V-D: both spike algorithms must settle to similar
    // mean calcium (paper Figs. 8/9 show matching medians ~ target).
    let mut cfg_old = SimConfig::paper_quality(6_000);
    cfg_old.ranks = 8; // scale down for CI speed; still cross-rank only
    cfg_old.spike_alg = SpikeAlg::OldIds;
    cfg_old.connectivity_alg = ConnectivityAlg::NewLocationAware;
    let mut cfg_new = cfg_old.clone();
    cfg_new.spike_alg = SpikeAlg::NewFrequency;

    let old = run_simulation(&cfg_old).unwrap();
    let new = run_simulation(&cfg_new).unwrap();
    let (ca_old, ca_new) = (old.mean_calcium(), new.mean_calcium());
    assert!(ca_old > 0.2, "network inactive under old spikes: {ca_old}");
    assert!(ca_new > 0.2, "network inactive under new spikes: {ca_new}");
    assert!(
        (ca_old - ca_new).abs() < 0.15,
        "calcium diverges: old {ca_old:.3} vs new {ca_new:.3}"
    );
}

#[test]
fn homeostasis_approaches_target() {
    // Longer single-algorithm run: mean calcium should climb towards the
    // 0.7 target (scaled-down Fig. 8 trajectory).
    let mut cfg = SimConfig::paper_quality(20_000);
    cfg.ranks = 8;
    let report = run_simulation(&cfg).unwrap();
    let ca = report.mean_calcium();
    assert!(ca > 0.45, "calcium {ca} did not rise towards target");
    assert!(report.total_synapses() > 0);
}

#[test]
fn spike_byte_volume_advantage_at_high_activity() {
    // With connectivity in place and activity near target, the old
    // algorithm ships every spike id each step while the new one ships
    // 12 B per neuron-partner pair per 100-step epoch.
    let mut cfg_old = base_cfg();
    cfg_old.steps = 2_000;
    cfg_old.spike_alg = SpikeAlg::OldIds;
    let mut cfg_new = cfg_old.clone();
    cfg_new.spike_alg = SpikeAlg::NewFrequency;
    let old = run_simulation(&cfg_old).unwrap();
    let new = run_simulation(&cfg_new).unwrap();
    // Old pays a collective every step; new only at epochs + plasticity.
    let old_coll: u64 = old.ranks.iter().map(|r| r.comm.collectives).sum();
    let new_coll: u64 = new.ranks.iter().map(|r| r.comm.collectives).sum();
    assert!(
        old_coll > 10 * new_coll,
        "synchronization points: old {old_coll} vs new {new_coll}"
    );
}

#[test]
fn theta_zero_matches_direct_more_closely_than_large_theta() {
    // Sanity on the approximation knob: with theta=0 Barnes-Hut IS the
    // direct method (every candidate resolved to a leaf).
    let mut cfg = with_algs(ConnectivityAlg::NewLocationAware, SpikeAlg::NewFrequency);
    cfg.theta = 0.0;
    cfg.ranks = 1; // one rank: identical candidate sets, no branch cuts
    cfg.neurons_per_rank = 128;
    let bh = run_simulation(&cfg).unwrap();
    let mut dcfg = cfg.clone();
    dcfg.connectivity_alg = ConnectivityAlg::Direct;
    let direct = run_simulation(&dcfg).unwrap();
    let (a, b) = (bh.total_synapses() as f64, direct.total_synapses() as f64);
    assert!((a - b).abs() / a.max(b) < 0.1, "theta=0 {a} vs direct {b}");
}

#[test]
fn calcium_trace_recording_works() {
    let mut cfg = base_cfg();
    cfg.record_calcium_every = 50;
    cfg.steps = 200;
    let report = run_simulation(&cfg).unwrap();
    for r in &report.ranks {
        assert_eq!(r.calcium_trace.len(), 4); // steps 0, 50, 100, 150
        assert_eq!(r.calcium_trace[0].1.len(), cfg.neurons_per_rank);
    }
}

#[test]
fn phase_timers_cover_all_phases() {
    let report =
        run_simulation(&with_algs(ConnectivityAlg::OldRma, SpikeAlg::OldIds)).unwrap();
    use ilmi::metrics::Phase;
    for p in [Phase::SpikeExchange, Phase::ActivityUpdate, Phase::BarnesHut] {
        assert!(report.phase_max(p) > 0.0, "phase {p:?} has no recorded time");
    }
}

#[test]
fn poisson_model_wires_up_too() {
    // The plasticity machinery is neuron-model agnostic (paper §III-A):
    // the rate model must also grow a network.
    let mut cfg = base_cfg();
    cfg.neuron_model = ilmi::config::NeuronModel::Poisson;
    cfg.steps = 600;
    let report = run_simulation(&cfg).unwrap();
    assert!(report.total_synapses() > 0, "poisson network formed nothing");
    let out: usize = report.ranks.iter().map(|r| r.synapses_out).sum();
    let inn: usize = report.ranks.iter().map(|r| r.synapses_in).sum();
    assert_eq!(out, inn);
}

#[test]
fn network_model_prices_new_algorithms_cheaper() {
    // Re-pricing the counted communication on cluster-class constants
    // must favour the new algorithms even more than wall-clock does
    // (they trade many latency-bound operations for few larger ones).
    use ilmi::metrics::NetModel;
    let old = run_simulation(&with_algs(ConnectivityAlg::OldRma, SpikeAlg::OldIds)).unwrap();
    let new = run_simulation(&with_algs(
        ConnectivityAlg::NewLocationAware,
        SpikeAlg::NewFrequency,
    ))
    .unwrap();
    for model in [NetModel::hdr100(), NetModel::ethernet25g()] {
        let po = model.price_run(&old.ranks.iter().map(|r| r.comm).collect::<Vec<_>>());
        let pn = model.price_run(&new.ranks.iter().map(|r| r.comm).collect::<Vec<_>>());
        assert!(
            po > 5.0 * pn,
            "modeled network cost should strongly favour new: {po} vs {pn}"
        );
    }
}

#[test]
fn delta_sweep_trades_bytes_for_staleness() {
    // Larger frequency epochs -> fewer bytes on the spike path, with
    // homeostasis still functional.
    let mut small = base_cfg();
    small.delta = 20;
    small.steps = 600;
    let mut large = small.clone();
    large.delta = 200;
    let s = run_simulation(&small).unwrap();
    let l = run_simulation(&large).unwrap();
    // Byte ordering on the spike path shows through total sent bytes
    // (connectivity traffic is identical in expectation).
    assert!(
        s.total_bytes_sent() > l.total_bytes_sent(),
        "delta=20 should send more than delta=200: {} vs {}",
        s.total_bytes_sent(),
        l.total_bytes_sent()
    );
    assert!(l.total_synapses() > 0);
}
