//! Distribution-level equivalence of the three target-search algorithms.
//!
//! The paper's correctness argument (§V-A) is that the location-aware
//! algorithm evaluates the SAME selection distribution as the original,
//! only with different PRNG state. We verify it empirically: over many
//! independent formation rounds on a fixed 4-rank scenario, the
//! distribution of chosen targets (aggregated per rank) must agree
//! between old, new, and — for moderate θ — the direct O(n²) solution.

use ilmi::comm::run_ranks;
use ilmi::config::{ConnectivityAlg, SimConfig};
use ilmi::coordinator::RankState;
use ilmi::plasticity::SynapseStore;
use ilmi::util::Rng;

const RANKS: usize = 4;
const NPR: usize = 16;
const ROUNDS: usize = 250;

/// Run `ROUNDS` independent single-search formation rounds with `alg`;
/// return, for rank 0's neuron 0, the histogram of chosen target ranks.
fn target_rank_histogram(alg: ConnectivityAlg, seed: u64) -> Vec<usize> {
    let cfg = SimConfig {
        ranks: RANKS,
        neurons_per_rank: NPR,
        connectivity_alg: alg,
        theta: 0.3,
        seed,
        ..SimConfig::default()
    };
    let results = run_ranks(cfg.ranks, |comm| {
        let mut state = RankState::init(&cfg, &comm);
        // Freeze a scenario: everyone offers dendrites, only rank 0's
        // neuron 0 searches (one vacant excitatory axonal element).
        for i in 0..NPR {
            state.pop.z_ax[i] = 0.0;
            state.pop.z_den_exc[i] = 4.0;
            state.pop.z_den_inh[i] = 4.0;
        }
        if comm.rank() == 0 {
            state.pop.z_ax[0] = 1.0;
            state.pop.is_excitatory[0] = true;
        }
        let mut hist = vec![0usize; RANKS];
        for round in 0..ROUNDS {
            // Fresh store each round -> i.i.d. samples of the first choice.
            state.store = SynapseStore::new(NPR, NPR as u64);
            state.rng_conn = Rng::new(seed ^ (round as u64 * 7919));
            state.plasticity_phase(&cfg, &comm);
            if comm.rank() == 0 {
                match state.store.out_edges[0].first() {
                    Some(&tgt) => hist[(tgt as usize) / NPR] += 1,
                    None => { /* failed search this round */ }
                }
            }
        }
        hist
    });
    results.into_iter().next().unwrap()
}

fn total_variation(a: &[usize], b: &[usize]) -> f64 {
    let sa: f64 = a.iter().sum::<usize>() as f64;
    let sb: f64 = b.iter().sum::<usize>() as f64;
    assert!(sa > 0.0 && sb > 0.0);
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 / sa - y as f64 / sb).abs())
        .sum::<f64>()
        / 2.0
}

#[test]
fn new_algorithm_samples_same_distribution_as_old() {
    let old = target_rank_histogram(ConnectivityAlg::OldRma, 42);
    let new = target_rank_histogram(ConnectivityAlg::NewLocationAware, 42);
    let tv = total_variation(&old, &new);
    assert!(
        tv < 0.12,
        "old {old:?} vs new {new:?}: total variation {tv:.3} too large"
    );
}

#[test]
fn barnes_hut_approximates_direct_distribution() {
    let new = target_rank_histogram(ConnectivityAlg::NewLocationAware, 43);
    let direct = target_rank_histogram(ConnectivityAlg::Direct, 43);
    let tv = total_variation(&new, &direct);
    // theta = 0.3 introduces approximation error; the paper accepts it
    // as qualitatively equivalent.
    assert!(
        tv < 0.15,
        "new {new:?} vs direct {direct:?}: total variation {tv:.3} too large"
    );
}

#[test]
fn searches_almost_always_succeed_in_dense_scenario() {
    // With 63 candidate neurons offering 4 elements each, a single
    // search should essentially never fail.
    let hist = target_rank_histogram(ConnectivityAlg::NewLocationAware, 44);
    let found: usize = hist.iter().sum();
    assert!(found >= ROUNDS * 95 / 100, "only {found}/{ROUNDS} searches succeeded");
}
