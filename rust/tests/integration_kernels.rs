//! Cross-kernel differential suite: the scalar, cache-blocked, and
//! staged-XLA `NeuronKernel` backends must be interchangeable execution
//! strategies (DESIGN.md §12).
//!
//! The same seeded configuration is run once per kernel over the thread
//! transport, and everything except wall-clock timing must be
//! bit-identical per rank: the ILMISNAP capture bytes (the full
//! dynamics state, RNG streams included), the deterministic fields of
//! the encoded `RankReport`, and every rank's `CounterSnapshot`. The
//! XLA column runs against the mock executor service (the native oracle
//! behind the staged service protocol), so the staging/unstaging path —
//! not the floating-point math — is what the comparison exercises.
//!
//! Coverage: both spike algorithms, both neuron models (Poisson is
//! scalar/blocked only — config validation pins the XLA exclusion), a
//! skewed load-balancing run (population sizes change mid-run under
//! migration), and checkpoint/resume legs that *switch kernels* at the
//! boundary — the kernel is excluded from the dynamics fingerprint, so
//! a snapshot taken under one backend must resume bit-exactly under
//! another.

use ilmi::bench::{AlgGen, Regime, RunSettings, Scenario};
use ilmi::comm::{run_ranks, Comm, CounterSnapshot};
use ilmi::config::{KernelKind, NeuronModel, SimConfig};
use ilmi::coordinator::{resume_simulation, resume_simulation_with_xla, run_simulation, RankState};
use ilmi::metrics::{RankReport, SimReport};
use ilmi::neuron::make_kernel;
use ilmi::runtime::{spawn_mock_service, XlaHandle};
use ilmi::snapshot::{snapshot_file_name, Snapshot};

// -- differential harness ------------------------------------------------

/// Everything one rank produces that must be kernel-independent.
type Digest = (Vec<u8>, Vec<u8>, Vec<CounterSnapshot>);

/// Encode a report with its wall-clock-derived fields zeroed; all
/// remaining bytes are functions of the seeded trajectory alone.
fn deterministic_bytes(mut r: RankReport) -> Vec<u8> {
    r.phase_seconds = Default::default();
    r.formation.compute_nanos = 0;
    r.formation.exchange_nanos = 0;
    for s in &mut r.trace {
        s.ts_micros = 0.0;
        s.phase_seconds = Default::default();
        s.cost.nanos = 0;
    }
    r.encode()
}

/// The per-rank simulation body: install the kernel under test, run
/// every step, then capture the ILMISNAP section, the quiesced per-rank
/// counter snapshots, and the deterministic report bytes.
fn rank_digest(cfg: &SimConfig, comm: &impl Comm, xla: Option<&XlaHandle>) -> Digest {
    let mut state = RankState::init(cfg, comm);
    state.kernel = make_kernel(cfg, xla);
    for step in 0..cfg.steps {
        state.step(cfg, comm, step).expect("step failed");
    }
    state.formation.compute_nanos = 0;
    state.formation.exchange_nanos = 0;
    let section = state.capture(comm);
    comm.barrier(); // quiesce: every rank's counters are final
    let all = comm.all_counters();
    (section, deterministic_bytes(state.into_report(comm)), all)
}

/// Run `cfg` once per kernel column and pin every digest against the
/// scalar oracle's. `with_xla` additionally runs the staged path
/// against the mock executor service (Izhikevich only).
fn assert_kernels_agree(cfg: &SimConfig, with_xla: bool, label: &str) {
    let digest_for = |kernel: KernelKind, xla: Option<XlaHandle>| -> Vec<Digest> {
        let mut c = cfg.clone();
        c.kernel = kernel;
        c.validate().expect("kernel config must validate");
        run_ranks(c.ranks, |comm| rank_digest(&c, &comm, xla.as_ref()))
    };
    let scalar = digest_for(KernelKind::Scalar, None);
    let mut columns = vec![("blocked", digest_for(KernelKind::Blocked, None))];
    if with_xla {
        let handle = spawn_mock_service();
        columns.push(("xla", digest_for(KernelKind::Xla, Some(handle.clone()))));
        handle.shutdown();
    }
    for (name, column) in columns {
        for (rank, (s, k)) in scalar.iter().zip(&column).enumerate() {
            assert_eq!(
                s.0, k.0,
                "{label}/{name}: rank {rank} ILMISNAP section bytes differ"
            );
            assert_eq!(s.1, k.1, "{label}/{name}: rank {rank} report bytes differ");
            assert_eq!(s.2, k.2, "{label}/{name}: rank {rank} counter snapshots differ");
        }
    }
}

fn smoke_settings() -> RunSettings {
    RunSettings { steps: 60, plasticity_interval: 30, warmup: 0, reps: 1, seed: 42 }
}

fn smoke_cfg(alg: AlgGen) -> SimConfig {
    Scenario {
        alg,
        ranks: 2,
        neurons_per_rank: 16,
        delta: 30,
        regime: Regime::Active,
        skew: false,
        kernel: KernelKind::Scalar,
    }
    .config(&smoke_settings())
}

// -- kernel equivalence, straight runs -----------------------------------

#[test]
fn izhikevich_kernels_are_bit_identical_new_algorithms() {
    let mut cfg = smoke_cfg(AlgGen::New);
    // Tracing on: epoch samples must be identical across kernels too.
    cfg.trace_every = 30;
    cfg.trace_capacity = 8;
    assert_kernels_agree(&cfg, true, "new/izhikevich");
}

#[test]
fn izhikevich_kernels_are_bit_identical_old_algorithms() {
    // The old generation's RMA downloads ride the same step loop; the
    // kernel must not perturb the octree/spike-id paths either.
    let cfg = smoke_cfg(AlgGen::Old);
    assert_kernels_agree(&cfg, true, "old/izhikevich");
}

#[test]
fn poisson_scalar_and_blocked_are_bit_identical() {
    // Poisson draws exactly one uniform per neuron in index order; the
    // blocked walk must preserve that RNG stream bit-for-bit. The XLA
    // column is excluded by config validation (native-only model).
    let mut cfg = smoke_cfg(AlgGen::New);
    cfg.neuron_model = NeuronModel::Poisson;
    assert_kernels_agree(&cfg, false, "new/poisson");

    let mut xla = cfg.clone();
    xla.kernel = KernelKind::Xla;
    let err = xla.validate().expect_err("poisson + kernel=xla must be rejected");
    assert!(err.contains("poisson"), "{err}");
}

#[test]
fn skewed_balancing_run_is_kernel_independent() {
    // Migration changes per-rank population sizes mid-run: block counts
    // and tail handling shift under the blocked kernel, and the staged
    // XLA buffers must follow the resizes.
    let settings =
        RunSettings { steps: 150, plasticity_interval: 50, warmup: 0, reps: 1, seed: 42 };
    let cfg = Scenario {
        alg: AlgGen::New,
        ranks: 2,
        neurons_per_rank: 32,
        delta: 50,
        regime: Regime::Active,
        skew: true,
        kernel: KernelKind::Scalar,
    }
    .config(&settings);
    assert_kernels_agree(&cfg, true, "skewed balance run");
}

// -- checkpoint/resume across a kernel switch ----------------------------

/// The deterministic per-rank fields a resumed run must reproduce
/// against its straight-run twin. (Full report bytes are not comparable
/// across a resume split: `kernel_blocks` counts the executed segment.)
fn assert_reports_match(straight: &SimReport, resumed: &SimReport, tag: &str) {
    assert_eq!(straight.ranks.len(), resumed.ranks.len());
    for (s, r) in straight.ranks.iter().zip(&resumed.ranks) {
        assert_eq!(s.synapses_out, r.synapses_out, "{tag}: synapses_out");
        assert_eq!(s.synapses_in, r.synapses_in, "{tag}: synapses_in");
        assert_eq!(
            s.mean_calcium.to_bits(),
            r.mean_calcium.to_bits(),
            "{tag}: mean_calcium {} vs {}",
            s.mean_calcium,
            r.mean_calcium
        );
        assert_eq!(s.comm, r.comm, "{tag}: comm counters");
        assert_eq!(s.spike_lookups, r.spike_lookups, "{tag}: spike_lookups");
        assert_eq!(s.migrations, r.migrations, "{tag}: migrations");
    }
}

#[test]
fn resume_switches_kernels_bit_exactly() {
    // Straight 150-step run under the scalar oracle.
    let mut base = smoke_cfg(AlgGen::New);
    base.steps = 150;
    base.plasticity_interval = 50;
    base.delta = 50;
    let straight = run_simulation(&base).unwrap();

    // Leg 1: first 75 steps under the BLOCKED kernel, checkpointing.
    let dir = std::env::temp_dir().join(format!("ilmi_kernel_switch_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut first = base.clone();
    first.kernel = KernelKind::Blocked;
    first.steps = 75;
    first.checkpoint_every = 75;
    first.checkpoint_dir = dir.to_str().unwrap().to_string();
    run_simulation(&first).unwrap();
    let snap = Snapshot::read_file(dir.join(snapshot_file_name(75))).unwrap();
    assert_eq!(snap.next_step(), 75);

    // Leg 2a: resume under the SCALAR kernel. The kernel is excluded
    // from the dynamics fingerprint, so no --branch is needed.
    let resumed_scalar = resume_simulation(&base, &snap).unwrap();
    assert_reports_match(&straight, &resumed_scalar, "blocked->scalar");

    // Leg 2b: resume the same snapshot under the staged XLA kernel
    // (mock executor service).
    let mut xla_cfg = base.clone();
    xla_cfg.kernel = KernelKind::Xla;
    let handle = spawn_mock_service();
    let resumed_xla =
        resume_simulation_with_xla(&xla_cfg, &snap, Some(handle.clone())).unwrap();
    handle.shutdown();
    assert_reports_match(&straight, &resumed_xla, "blocked->xla");

    // kernel_blocks is per-segment work, not resumed: the straight run
    // counts all 150 steps, each leg-2 report only its own 75
    // (ceil(16/64) = 1 block per rank per step).
    assert_eq!(straight.total_kernel_blocks(), 150 * 2);
    assert_eq!(resumed_scalar.total_kernel_blocks(), 75 * 2);
    assert_eq!(resumed_xla.total_kernel_blocks(), 75 * 2);

    std::fs::remove_dir_all(&dir).ok();
}
