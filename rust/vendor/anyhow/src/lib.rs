//! Vendored, API-compatible subset of `anyhow` (dtolnay/anyhow).
//!
//! The offline crate set cannot reach crates.io, so this crate provides
//! exactly the surface the workspace uses: `Error`, `Result`, the
//! `anyhow!` / `bail!` macros, `Error::msg`, and the `Context` extension
//! trait for `Result` and `Option`. Error values carry a chain of
//! human-readable messages; `{}` prints the outermost message, `{:#}`
//! prints the whole chain separated by `": "` (matching anyhow's
//! alternate formatting, which `main.rs` relies on).

use std::fmt;

/// A type-erased error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>` — `std::result::Result` with `Error` as the
/// default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts into `Error`, preserving its source chain.
// (`Error` itself deliberately does NOT implement `std::error::Error`,
// exactly like the real anyhow, so this blanket impl is coherent.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted `Error`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn msg_and_display() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.root_cause(), "missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no {}", "value")).unwrap_err();
        assert_eq!(format!("{e}"), "no value");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} of {}", "state", 7);
        assert_eq!(format!("{e}"), "bad state of 7");
        fn fails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", fails().unwrap_err()), "nope 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn open() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(open().is_err());
    }
}
