//! Lesion-induced rewiring — the use case that motivates MSP (Butz &
//! van Ooyen 2013 model cortical reorganization after focal retinal
//! lesions; paper §I, §VI: "predict brain changes after learning,
//! lesions, or normal development").
//!
//! Protocol:
//!   1. Grow a healthy 8-rank network to (near-)equilibrium.
//!   2. Lesion rank 0's neurons: background input silenced, synaptic
//!      elements forced to zero — their calcium collapses, their
//!      elements retract, and the deletion protocol dismantles every
//!      synapse touching them.
//!   3. Keep simulating: the surviving neurons lost input, their calcium
//!      dips below target, they grow new elements and REWIRE among
//!      themselves.
//!
//! The example drives the per-rank `RankState` API directly (rather than
//! `run_simulation`) to inject the lesion mid-run, and prints the synapse
//! census before/after.
//!
//!     cargo run --release --example lesion_rewiring

use ilmi::comm::run_ranks;
use ilmi::config::SimConfig;
use ilmi::coordinator::RankState;

const LESION_RANK: usize = 0;

/// (synapses between healthy neurons, synapses touching the lesion,
/// mean calcium of this rank if healthy) — counted on the axonal side,
/// so summing over ranks counts each synapse exactly once.
fn census(state: &RankState, rank: usize, npr: u64) -> (usize, usize, f64) {
    let mut healthy = 0usize;
    let mut lesioned = 0usize;
    let src_lesioned = rank == LESION_RANK;
    for edges in &state.store.out_edges {
        for &tgt in edges {
            if src_lesioned || (tgt / npr) as usize == LESION_RANK {
                lesioned += 1;
            } else {
                healthy += 1;
            }
        }
    }
    let ca = if src_lesioned { 0.0 } else { state.pop.mean_calcium() };
    (healthy, lesioned, ca)
}

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig {
        ranks: 8,
        neurons_per_rank: 64,
        steps: 0, // stepping manually
        plasticity_interval: 100,
        delta: 100,
        ..SimConfig::default()
    };
    let grow_steps = 30_000;
    let post_lesion_steps = 30_000;
    let npr = cfg.neurons_per_rank as u64;

    println!(
        "lesion experiment: {} ranks x {} neurons; grow {} steps, lesion rank {}, recover {} steps",
        cfg.ranks, cfg.neurons_per_rank, grow_steps, LESION_RANK, post_lesion_steps
    );

    let results = run_ranks(cfg.ranks, |comm| {
        let rank = comm.rank();
        let mut cfg_rank = cfg.clone();
        let mut state = RankState::init(&cfg_rank, &comm);

        // Phase 1: grow to equilibrium.
        for step in 0..grow_steps {
            state.step(&cfg_rank, &comm, step).unwrap();
        }
        let before = census(&state, rank, npr);

        // Phase 2: lesion — silence rank 0's neurons. Their elements are
        // zeroed, so the next deletion phase breaks all their synapses
        // (partners are notified through the normal protocol).
        if rank == LESION_RANK {
            for i in 0..state.pop.len() {
                state.pop.z_ax[i] = 0.0;
                state.pop.z_den_exc[i] = 0.0;
                state.pop.z_den_inh[i] = 0.0;
                state.pop.ca[i] = 0.0;
            }
            // No more background drive: the neurons stay silent, their
            // growth curve stays negative, they never regrow.
            cfg_rank.bg_mean = 0.0;
            cfg_rank.bg_std = 0.0;
        }

        // Phase 3: recovery.
        let mut mid = None;
        for step in grow_steps..grow_steps + post_lesion_steps {
            state.step(&cfg_rank, &comm, step).unwrap();
            if step == grow_steps + 200 {
                mid = Some(census(&state, rank, npr));
            }
        }
        let after = census(&state, rank, npr);
        (before, mid.unwrap(), after)
    });

    let agg = |pick: fn(&(usize, usize, f64)) -> usize, which: usize| -> usize {
        results
            .iter()
            .map(|(b, m, a)| pick(match which {
                0 => b,
                1 => m,
                _ => a,
            }))
            .sum()
    };
    let ca_healthy = |which: usize| -> f64 {
        let v: Vec<f64> = results
            .iter()
            .enumerate()
            .filter(|(r, _)| *r != LESION_RANK)
            .map(|(_, (b, m, a))| match which {
                0 => b.2,
                1 => m.2,
                _ => a.2,
            })
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };

    let stages = ["pre-lesion", "post-lesion (200 steps)", "recovered"];
    println!(
        "{:<26} {:>16} {:>18} {:>14}",
        "stage", "healthy synapses", "touching lesion", "healthy Ca"
    );
    for (i, stage) in stages.iter().enumerate() {
        println!(
            "{:<26} {:>16} {:>18} {:>14.3}",
            stage,
            agg(|c| c.0, i),
            agg(|c| c.1, i),
            ca_healthy(i)
        );
    }

    let lesioned_after = agg(|c| c.1, 2);
    let healthy_before = agg(|c| c.0, 0);
    let healthy_after = agg(|c| c.0, 2);
    assert_eq!(lesioned_after, 0, "lesioned neurons must end fully disconnected");
    assert!(
        healthy_after > healthy_before,
        "survivors should rewire among themselves ({healthy_before} -> {healthy_after})"
    );
    println!("lesion rewiring OK: deafferented survivors formed replacement synapses.");
    Ok(())
}
