//! END-TO-END DRIVER — exercises every layer of the stack on one real
//! workload and reports the paper's headline metrics:
//!
//!   L1/L2  the JAX/Pallas neuron-update artifact (AOT-lowered HLO) is
//!          loaded through PJRT and executes EVERY simulation step;
//!   L3     the Rust coordinator runs the paper's timing workload
//!          (§V-B: 1000 steps / 10 plasticity updates, no initial
//!          connectivity, 1.1–1.5 vacant elements) on 16 simulated MPI
//!          ranks, once with the OLD algorithms (RMA Barnes–Hut +
//!          per-step spike ids) and once with the NEW ones
//!          (location-aware Barnes–Hut + frequency approximation).
//!
//! Printed at the end: phase breakdowns (Fig. 11 shape), byte totals
//! (Tables I/II shape), and the old/new speedup factors (the paper's
//! headline: connectivity ~6x, spikes >100x at 1024 ranks; scaled-down
//! here, the gap must still favour NEW). Results are recorded in
//! EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example full_pipeline

use ilmi::config::{Backend, ConnectivityAlg, SimConfig, SpikeAlg};
use ilmi::coordinator::run_simulation_with_xla;
use ilmi::metrics::Phase;
use ilmi::runtime::spawn_service;
use ilmi::util::format_bytes;

fn main() -> anyhow::Result<()> {
    let base = SimConfig {
        ranks: 16,
        neurons_per_rank: 256,
        steps: 1000,
        plasticity_interval: 100,
        delta: 100,
        theta: 0.3,
        backend: Backend::Xla,
        ..SimConfig::default()
    };
    println!(
        "full pipeline: {} ranks x {} neurons, {} steps, theta={}, backend=XLA (AOT artifacts)",
        base.ranks, base.neurons_per_rank, base.steps, base.theta
    );

    let handle = spawn_service(&base.artifacts_dir)?;
    println!("PJRT artifacts loaded; neuron batches {:?}", handle.neuron_batches()?);

    let mut old_cfg = base.clone();
    old_cfg.connectivity_alg = ConnectivityAlg::OldRma;
    old_cfg.spike_alg = SpikeAlg::OldIds;
    let mut new_cfg = base.clone();
    new_cfg.connectivity_alg = ConnectivityAlg::NewLocationAware;
    new_cfg.spike_alg = SpikeAlg::NewFrequency;

    println!("\n-- OLD algorithms --");
    let old = run_simulation_with_xla(&old_cfg, Some(handle.clone()))?;
    print!("{}", old.phase_table());

    println!("\n-- NEW algorithms --");
    let new = run_simulation_with_xla(&new_cfg, Some(handle.clone()))?;
    print!("{}", new.phase_table());
    handle.shutdown();

    // Headline metrics (paper §V-E shape).
    let conn_old = old.phase_max(Phase::BarnesHut) + old.phase_max(Phase::SynapseExchange);
    let conn_new = new.phase_max(Phase::BarnesHut) + new.phase_max(Phase::SynapseExchange);
    let spike_old = old.phase_max(Phase::SpikeExchange);
    let spike_new = new.phase_max(Phase::SpikeExchange);
    let lookup_old = old.phase_max(Phase::SpikeLookup);
    let lookup_new = new.phase_max(Phase::SpikeLookup);
    let bytes_old = old.total_bytes_sent() + old.total_bytes_rma();
    let bytes_new = new.total_bytes_sent() + new.total_bytes_rma();

    println!("\n== headline metrics (old vs new) ==");
    println!("connectivity update : {conn_old:.4}s vs {conn_new:.4}s  ({:.2}x)", conn_old / conn_new.max(1e-12));
    println!("spike transmission  : {spike_old:.4}s vs {spike_new:.4}s  ({:.2}x)", spike_old / spike_new.max(1e-12));
    println!("spike look-up       : {lookup_old:.4}s vs {lookup_new:.4}s  ({:.2}x — new pays a small PRNG premium)", lookup_old / lookup_new.max(1e-12));
    println!(
        "transferred data    : {} vs {}  ({:.2}x)",
        format_bytes(bytes_old),
        format_bytes(bytes_new),
        bytes_old as f64 / bytes_new.max(1) as f64
    );
    println!(
        "RMA bytes           : {} vs {} (new algorithm: zero by construction)",
        format_bytes(old.total_bytes_rma()),
        format_bytes(new.total_bytes_rma())
    );
    println!(
        "wall clock          : {:.3}s vs {:.3}s  ({:.1}% reduction; paper: 78.8% at 1024 ranks)",
        old.wall_seconds,
        new.wall_seconds,
        100.0 * (1.0 - new.wall_seconds / old.wall_seconds)
    );
    println!(
        "synapses formed     : {} (old) vs {} (new)",
        old.total_synapses(),
        new.total_synapses()
    );

    // The paper's qualitative claims, asserted.
    assert!(new.total_bytes_rma() == 0, "new algorithm must not RMA");
    assert!(conn_new < conn_old, "location-aware connectivity must be faster");
    assert!(spike_new < spike_old, "frequency exchange must be faster");
    assert!(new.total_synapses() > 0 && old.total_synapses() > 0);
    println!("\nfull pipeline OK — all layers composed (Pallas kernel -> HLO -> PJRT -> coordinator).");
    Ok(())
}
