//! BENCHMARK-HARNESS TOUR — runs a tiny scenario matrix through the
//! `bench` subsystem's library API (the `ilmi bench` subcommand is the
//! same machinery behind flags) and demonstrates the full trajectory
//! workflow:
//!
//!   1. build a matrix: {old, new} x 2 ranks x 32 neurons/rank,
//!   2. run it (warmup + repetitions, per-phase medians),
//!   3. emit the versioned BENCH_*.json and re-read it,
//!   4. diff the run against its own file — the workflow CI uses to
//!      gate regressions (EXPERIMENTS.md §Bench documents the schema).
//!
//!     cargo run --release --example bench_matrix

use ilmi::bench::{run_matrix, AlgGen, BenchReport, MatrixSpec, Regime, RunSettings};
use ilmi::metrics::ALL_PHASES;

fn main() -> anyhow::Result<()> {
    let spec = MatrixSpec {
        algs: vec![AlgGen::Old, AlgGen::New],
        ranks: vec![2],
        neurons: vec![32],
        deltas: vec![50],
        regimes: vec![Regime::Active],
        skew: false,
    };
    let settings =
        RunSettings { steps: 100, plasticity_interval: 50, warmup: 1, reps: 3, seed: 42 };

    let report = run_matrix("example", &spec, &settings, |msg| println!("{msg}"))?;
    print!("{}", report.markdown_table());

    // The JSON trajectory round-trips exactly.
    let path = std::env::temp_dir().join("BENCH_example.json");
    std::fs::write(&path, report.to_json())?;
    let reread = BenchReport::from_json(&std::fs::read_to_string(&path)?)
        .map_err(anyhow::Error::msg)?;
    assert_eq!(reread, report);
    println!("wrote and re-read {} ({} scenarios)", path.display(), reread.results.len());

    // Self-diff: same workload fingerprint, zero regressions by
    // construction — the shape of a CI baseline gate.
    let diff = report.diff(&reread, 0.2).map_err(anyhow::Error::msg)?;
    print!("{}", diff.render());
    assert_eq!(diff.regressions(), 0);

    // The headline the matrix exists to show: the new generation moves
    // fewer bytes on the same workload.
    let total = |alg: AlgGen| {
        report
            .results
            .iter()
            .filter(|r| r.scenario.alg == alg)
            .map(|r| r.comm.bytes_sent + r.comm.bytes_rma)
            .sum::<u64>()
    };
    let (old, new) = (total(AlgGen::Old), total(AlgGen::New));
    println!("bytes old {old} vs new {new} ({:.1}x)", old as f64 / new.max(1) as f64);
    for p in ALL_PHASES {
        let med = |alg: AlgGen| {
            report
                .results
                .iter()
                .find(|r| r.scenario.alg == alg)
                .map(|r| r.phases[p.index()].median)
                .unwrap_or(0.0)
        };
        println!("{:<18} old {:.4}s new {:.4}s", p.name(), med(AlgGen::Old), med(AlgGen::New));
    }
    std::fs::remove_file(&path).ok();
    Ok(())
}
