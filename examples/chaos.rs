//! Chaos drill: kill a rank process mid-run AND corrupt the newest
//! checkpoint, then watch the supervisor put the fleet back together —
//! bit-identically (DESIGN.md §13, EXPERIMENTS.md §Fault tolerance).
//!
//! Protocol:
//!   1. Run a 2-process socket fleet for 240 steps, checkpointing every
//!      40 into a retention ring of 3, with no faults: the reference
//!      trajectory. Record the final snapshot's bytes.
//!   2. Wipe the checkpoint directory and rerun the SAME config with a
//!      seeded fault plan: the step-160 checkpoint is written truncated
//!      (it will fail its whole-file content checksum), and rank 1 is
//!      killed at step 180 — after the corrupt checkpoint, before the next good one.
//!   3. The supervisor reaps the dead fleet, scans the ring, rejects
//!      the corrupt step-160 file, resumes everyone from step 120, and
//!      the relaunched fleet — fault plan filtered to attempt 1, so the
//!      kill does not re-fire — finishes the schedule.
//!   4. Print the recovery ledger and assert the recovered final
//!      snapshot is byte-for-byte identical to the reference: faults
//!      cost wall time, never trajectory.
//!
//!     cargo run --release --example chaos

use ilmi::config::{CommBackend, SimConfig};
use ilmi::coordinator::run_simulation;
use ilmi::snapshot::snapshot_file_name;

fn base_config(dir: &std::path::Path) -> SimConfig {
    let mut cfg = SimConfig {
        ranks: 2,
        neurons_per_rank: 16,
        steps: 240,
        plasticity_interval: 40,
        delta: 40,
        ..SimConfig::default()
    };
    cfg.comm_backend = CommBackend::Socket;
    cfg.checkpoint_every = 40;
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg.checkpoint_keep = 3;
    cfg.max_recoveries = 3;
    cfg
}

fn main() -> anyhow::Result<()> {
    // Socket-backend rank processes re-exec this binary; the child hook
    // must run before anything else.
    ilmi::comm::proc::maybe_run_child(ilmi::coordinator::SOCKET_ENTRIES);

    let dir = std::env::temp_dir().join(format!("ilmi_chaos_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let cfg = base_config(&dir);
    cfg.validate().map_err(anyhow::Error::msg)?;

    println!(
        "chaos: {} ranks x {} neurons, {} steps, checkpoint every {} (ring of {})",
        cfg.ranks, cfg.neurons_per_rank, cfg.steps, cfg.checkpoint_every, cfg.checkpoint_keep
    );
    println!("\n-- reference run (no faults) --");
    let clean = run_simulation(&cfg)?;
    assert_eq!(clean.recoveries, 0);
    let final_name = snapshot_file_name(cfg.steps as u64);
    let reference = std::fs::read(dir.join(&final_name))?;
    println!(
        "reference finished: wall {:.2}s, final snapshot {} ({} bytes)",
        clean.wall_seconds,
        final_name,
        reference.len()
    );

    // Same directory ⇒ the embedded config INI matches the reference
    // run's, so the snapshot files are byte-comparable.
    std::fs::remove_dir_all(&dir)?;
    std::fs::create_dir_all(&dir)?;
    let mut chaotic = cfg.clone();
    chaotic.fault_plan = "ckpt_corrupt:step=160;kill:rank=1,step=180".to_string();

    println!("\n-- chaos run: corrupt the step-160 checkpoint, kill rank 1 at step 180 --");
    let report = run_simulation(&chaotic)?;
    let recovered = std::fs::read(dir.join(&final_name))?;

    println!("\n{:<22} {:>12}", "recovery ledger", "");
    println!("{:<22} {:>12}", "recoveries", report.recoveries);
    println!("{:<22} {:>12}", "lost steps (>=)", report.lost_steps);
    println!("{:<22} {:>11.3}s", "recovery wall", report.recovery_seconds);
    println!("{:<22} {:>11.2}s", "total wall", report.wall_seconds);
    for r in &report.ranks {
        println!("rank {}: {} recoveries carried in its report", r.rank, r.recoveries);
    }

    assert_eq!(report.recoveries, 1, "one supervised relaunch");
    // The corrupt step-160 file was rejected, so the fleet resumed from
    // step 120: the 40 steps between are the provable replay cost.
    assert_eq!(report.lost_steps, 40, "evidence says steps 120..160 were replayed");
    assert_eq!(
        reference, recovered,
        "recovered final snapshot must be byte-identical to the reference"
    );
    println!(
        "\nchaos OK: killed + corrupted, recovered from the ring, and the final \
         snapshot is byte-identical to the clean run."
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
