//! Paper §V-D quality experiment (Figs. 8 and 9): 32 neurons on 32
//! ranks (one each, so ALL connectivity is cross-rank and the spike
//! approximation is fully exercised), target calcium 0.7, growth rate
//! 0.001, background N(5,1).
//!
//! Runs the experiment twice — once with the OLD per-step spike-id
//! exchange, once with the NEW frequency approximation — writes both
//! calcium traces to CSV, and prints the quartile boxes the paper plots
//! every 50,000 steps. The claim under test: the approximation changes
//! only the statistics' spread, not the homeostatic trajectory.
//!
//!     cargo run --release --example calcium_homeostasis -- [--steps N]
//!
//! Default 200,000 steps (2000 connectivity updates), as in the paper.

use ilmi::cli::Args;
use ilmi::config::{SimConfig, SpikeAlg};
use ilmi::coordinator::run_simulation;

fn quartiles(mut xs: Vec<f32>) -> (f32, f32, f32) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |f: f64| xs[((xs.len() - 1) as f64 * f).round() as usize];
    (q(0.25), q(0.5), q(0.75))
}

fn run(alg: SpikeAlg, steps: usize, csv_path: &str) -> anyhow::Result<()> {
    let mut cfg = SimConfig::paper_quality(steps);
    cfg.spike_alg = alg;
    let label = match alg {
        SpikeAlg::OldIds => "old (per-step spike ids)",
        SpikeAlg::NewFrequency => "new (frequency approximation)",
    };
    println!("== {label} ==");
    let report = run_simulation(&cfg)?;

    // Assemble the 32-neuron calcium matrix (one neuron per rank).
    let recorded = report.ranks[0].calcium_trace.len();
    let mut csv = String::from("step");
    for r in 0..cfg.ranks {
        csv.push_str(&format!(",ca_{r}"));
    }
    csv.push('\n');
    for k in 0..recorded {
        csv.push_str(&report.ranks[0].calcium_trace[k].0.to_string());
        for r in &report.ranks {
            csv.push_str(&format!(",{:.5}", r.calcium_trace[k].1[0]));
        }
        csv.push('\n');
    }
    std::fs::write(csv_path, csv)?;
    println!("trace -> {csv_path}");

    // Paper-style quartile boxes every 50k steps (or 4 slices if fewer).
    let box_every = (steps / 4).max(cfg.record_calcium_every);
    println!("{:>8} {:>8} {:>8} {:>8}", "step", "q25", "median", "q75");
    for k in 0..recorded {
        let (step, _) = report.ranks[0].calcium_trace[k];
        if step > 0 && step % box_every == 0 {
            let cas: Vec<f32> =
                report.ranks.iter().map(|r| r.calcium_trace[k].1[0]).collect();
            let (q25, med, q75) = quartiles(cas);
            println!("{step:>8} {q25:>8.3} {med:>8.3} {q75:>8.3}");
        }
    }
    let final_cas: Vec<f32> =
        report.ranks.iter().map(|r| *r.calcium_trace.last().unwrap().1.first().unwrap()).collect();
    let (q25, med, q75) = quartiles(final_cas);
    println!(
        "final: q25 {q25:.3} median {med:.3} q75 {q75:.3} (target {}) | synapses {}",
        cfg.neuron.eps_target_ca,
        report.total_synapses()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // Examples have no subcommand; give the parser a placeholder.
    let mut argv = vec!["run".to_string()];
    argv.extend(std::env::args().skip(1));
    let args = Args::parse(&argv).map_err(anyhow::Error::msg)?;
    let steps =
        args.get_parse::<usize>("steps").map_err(anyhow::Error::msg)?.unwrap_or(200_000);
    println!(
        "calcium homeostasis (paper SS V-D, Figs. 8/9): 32 neurons / 32 ranks, {steps} steps"
    );
    run(SpikeAlg::OldIds, steps, "/tmp/ilmi_fig8_old.csv")?;
    run(SpikeAlg::NewFrequency, steps, "/tmp/ilmi_fig9_new.csv")?;
    println!("done; compare the two CSVs / quartile tables.");
    Ok(())
}
