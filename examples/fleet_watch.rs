//! Fleet watch: the live telemetry plane end to end (DESIGN.md §14,
//! EXPERIMENTS.md §Live telemetry).
//!
//! Protocol:
//!   1. Launch a supervised 2-process socket fleet with heartbeats every
//!      5 steps, a 3-miss watchdog budget, and `--status-dir` aggregation
//!      — plus an injected HANG: rank 1's first data frame at/after step
//!      120 stalls for an hour. A hang is the failure mode a plain
//!      exit-status supervisor cannot see: nothing dies, nothing reports.
//!   2. While the fleet runs, a watcher thread polls the status
//!      directory the way `ilmi status <dir>` does and prints every
//!      state transition it observes (running -> recovering -> running
//!      -> done) with the per-rank table.
//!   3. The starving heartbeat stream trips the supervisor's watchdog,
//!      which kills, reaps, and relaunches the fleet from the step-100
//!      checkpoint — the same recovery loop a crashed rank takes.
//!   4. Assert the run recovered exactly once, the final status reads
//!      `done`, and telemetry stayed pure observation: the final
//!      snapshot is byte-identical to a telemetry-free clean run's.
//!
//!     cargo run --release --example fleet_watch

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ilmi::config::{CommBackend, SimConfig};
use ilmi::coordinator::run_simulation;
use ilmi::snapshot::snapshot_file_name;
use ilmi::telemetry::render_status;

fn base_config(ckpt_dir: &std::path::Path) -> SimConfig {
    let mut cfg = SimConfig {
        ranks: 2,
        neurons_per_rank: 16,
        steps: 200,
        plasticity_interval: 50,
        delta: 50,
        ..SimConfig::default()
    };
    cfg.comm_backend = CommBackend::Socket;
    cfg.checkpoint_every = 50;
    cfg.checkpoint_dir = ckpt_dir.to_string_lossy().into_owned();
    cfg.max_recoveries = 2;
    cfg
}

fn main() -> anyhow::Result<()> {
    // Socket-backend rank processes re-exec this binary; the child hook
    // must run before anything else.
    ilmi::comm::proc::maybe_run_child(ilmi::coordinator::SOCKET_ENTRIES);

    let root = std::env::temp_dir().join(format!("ilmi_watch_{}", std::process::id()));
    let ckpt_dir = root.join("ckpts");
    let status_dir = root.join("status");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&ckpt_dir)?;

    // Reference: the same schedule with telemetry off, for the purity
    // check in step 4 (same checkpoint dir => byte-comparable files).
    let clean_cfg = base_config(&ckpt_dir);
    clean_cfg.validate().map_err(anyhow::Error::msg)?;
    println!("-- reference run (telemetry off, no faults) --");
    let clean = run_simulation(&clean_cfg)?;
    assert_eq!(clean.recoveries, 0);
    let final_name = snapshot_file_name(clean_cfg.steps as u64);
    let reference = std::fs::read(ckpt_dir.join(&final_name))?;
    std::fs::remove_dir_all(&ckpt_dir)?;
    std::fs::create_dir_all(&ckpt_dir)?;

    let mut cfg = base_config(&ckpt_dir);
    cfg.telemetry_every = 5;
    cfg.telemetry_watchdog_misses = 3;
    cfg.status_dir = status_dir.to_string_lossy().into_owned();
    // Rank 1 stalls for an hour before its first data frame at/after
    // step 120: without the watchdog, the run would ride out a
    // transport read timeout at best.
    cfg.fault_plan = "frame_delay:rank=1,nth=1,ms=3600000,step=120".to_string();
    cfg.validate().map_err(anyhow::Error::msg)?;

    println!(
        "\n-- watched run: beats every {} steps, watchdog after {} misses, hang at step 120 --",
        cfg.telemetry_every, cfg.telemetry_watchdog_misses
    );
    // The watcher is exactly what `ilmi status <dir>` does, in a loop:
    // read status.json (atomically rewritten by the supervisor), render,
    // print on every state transition.
    let stop = Arc::new(AtomicBool::new(false));
    let watcher = {
        let stop = Arc::clone(&stop);
        let dir = status_dir.clone();
        std::thread::spawn(move || {
            let mut last_state = String::new();
            while !stop.load(Ordering::Relaxed) {
                if let Ok(table) = render_status(&dir) {
                    let state = table.lines().next().unwrap_or("").to_string();
                    if state != last_state {
                        println!("\n[watch]\n{table}");
                        last_state = state;
                    }
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        })
    };

    let report = run_simulation(&cfg)?;
    stop.store(true, Ordering::Relaxed);
    watcher.join().expect("watcher thread");

    println!("\n{:<22} {:>12}", "recovery ledger", "");
    println!("{:<22} {:>12}", "recoveries", report.recoveries);
    println!("{:<22} {:>11.3}s", "recovery wall", report.recovery_seconds);
    println!("{:<22} {:>11.2}s", "total wall", report.wall_seconds);

    let final_table = render_status(&status_dir).map_err(anyhow::Error::msg)?;
    println!("\n-- final `ilmi status` --\n{final_table}");

    assert_eq!(report.recoveries, 1, "one watchdog-driven relaunch");
    assert!(final_table.starts_with("state done"), "{final_table}");
    let recovered = std::fs::read(ckpt_dir.join(&final_name))?;
    assert_eq!(
        reference, recovered,
        "telemetry + watchdog recovery must not move the trajectory"
    );
    println!(
        "fleet_watch OK: the hang was invisible to exit statuses, the heartbeat \
         watchdog caught it, and the final snapshot is byte-identical to the \
         telemetry-free clean run."
    );
    let _ = std::fs::remove_dir_all(&root);
    Ok(())
}
