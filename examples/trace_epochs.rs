//! Epoch-granular telemetry — watch a run breathe, one plasticity epoch
//! at a time (EXPERIMENTS.md §Tracing).
//!
//! Protocol:
//!   1. Run a 2-rank network with tracing on (`instrumentation.
//!      trace_every = 50`, the plasticity interval): at every epoch
//!      boundary each rank records an `EpochSample` — per-phase time
//!      deltas, comm-counter deltas, spikes fired, synapses formed and
//!      retracted, plan rebuilds, migrations, and its step cost —
//!      into a bounded ring.
//!   2. Print the rank-0 time series: the windowed deltas tile the run,
//!      so summing any column reproduces the run total for that rank.
//!   3. Export the merged report both ways — Chrome `trace_event` JSON
//!      (open in Perfetto: one process per rank, phase slices plus
//!      counter tracks) and a JSONL time series — and check the event
//!      count against its closed form: every sample contributes all
//!      seven phase slices plus three counter points, so the count is a
//!      pure function of seed + config, never of timing.
//!
//!     cargo run --release --example trace_epochs

use ilmi::config::SimConfig;
use ilmi::coordinator::run_simulation;
use ilmi::metrics::ALL_PHASES;
use ilmi::trace::{boundary_names, chrome_trace, event_count, trace_jsonl, EVENTS_PER_SAMPLE};

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig {
        ranks: 2,
        neurons_per_rank: 32,
        steps: 250,
        plasticity_interval: 50,
        delta: 50,
        trace_every: 50,
        trace_capacity: 64,
        ..SimConfig::default()
    };
    cfg.validate().map_err(anyhow::Error::msg)?;
    println!(
        "trace_epochs: {} neurons over {} ranks, {} steps, sampling every {} steps",
        cfg.total_neurons(),
        cfg.ranks,
        cfg.steps,
        cfg.trace_every,
    );

    let report = run_simulation(&cfg)?;

    // The rank-0 time series: each row is the delta over one window.
    println!(
        "\n{:>6} {:>18} {:>8} {:>8} {:>10} {:>12} {:>10}",
        "step", "boundaries", "spikes", "formed", "bytes_sent", "plan_builds", "cost"
    );
    let r0 = &report.ranks[0];
    for s in &r0.trace {
        println!(
            "{:>6} {:>18} {:>8} {:>8} {:>10} {:>12} {:>10.0}",
            s.step,
            boundary_names(s.boundaries).join("+"),
            s.spikes,
            s.formed,
            s.comm.bytes_sent,
            s.plan_rebuilds,
            s.cost.cost(),
        );
    }

    // Windowed deltas tile the run: the columns sum back to the totals.
    let epochs = cfg.steps / cfg.trace_every;
    assert_eq!(r0.trace.len(), epochs, "one sample per epoch boundary");
    let formed: u64 = r0.trace.iter().map(|s| s.formed).sum();
    assert_eq!(formed, r0.formation.formed, "formation deltas must tile the run");
    let sent: u64 = r0.trace.iter().map(|s| s.comm.bytes_sent).sum();
    assert_eq!(sent, r0.comm.bytes_sent, "comm deltas must tile the run");

    // Export both ways and check the deterministic closed form: per
    // sample, seven phase slices + three counter points, plus one
    // cluster-imbalance point per aligned epoch.
    let chrome = chrome_trace(&report);
    let jsonl = trace_jsonl(&report);
    let expected = cfg.ranks as u64 * epochs as u64 * EVENTS_PER_SAMPLE + epochs as u64;
    assert_eq!(event_count(&report), expected, "event count must match its closed form");
    assert_eq!(jsonl.lines().count(), cfg.ranks * epochs, "one JSONL line per rank-sample");
    for p in ALL_PHASES {
        assert!(chrome.contains(p.name()), "phase {} missing from the trace", p.name());
    }

    let dir = std::env::temp_dir().join("ilmi_trace_epochs");
    std::fs::create_dir_all(&dir)?;
    let chrome_path = dir.join("trace.json");
    let jsonl_path = dir.join("trace.jsonl");
    std::fs::write(&chrome_path, &chrome)?;
    std::fs::write(&jsonl_path, &jsonl)?;
    println!(
        "\nwrote {} ({} events; load in Perfetto / chrome://tracing) and {}",
        chrome_path.display(),
        event_count(&report),
        jsonl_path.display()
    );
    println!("trace_epochs OK: {} samples per rank, deltas tile the run exactly.", epochs);
    Ok(())
}
