//! Dynamic load balancing in action — "move the computation" applied to
//! the partitioning itself (EXPERIMENTS.md §Load balancing).
//!
//! Protocol:
//!   1. Start a 2-rank network from a deliberately skewed partition:
//!      rank 0 owns 6 of the 8 Morton cells (48 of 64 neurons), rank 1
//!      only 2 (16 neurons) — `balance.init_cells = "6,2"`.
//!   2. Simulate with balancing enabled (`balance.every = 50`). At each
//!      balance epoch the ranks gather per-rank step costs
//!      (neurons + stored edges + remote partners), and whenever the
//!      max/mean imbalance factor exceeds the threshold the busiest
//!      rank's boundary Morton cell — computation, not just data —
//!      migrates to its lighter neighbor through the ordinary
//!      all-to-all.
//!   3. Print the per-rank cost and the imbalance factor at every
//!      epoch: it starts near 1.5 and falls to ~1.0 as the 48/16 split
//!      irons out to 32/32, while `SynapseStore::check_invariants` and
//!      `DeliveryPlan::check_against` hold after every migration.
//!
//!     cargo run --release --example rebalance

use ilmi::balance::imbalance;
use ilmi::comm::{gather_all, run_ranks};
use ilmi::config::SimConfig;
use ilmi::coordinator::RankState;

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig {
        ranks: 2,
        neurons_per_rank: 32,
        steps: 250,
        plasticity_interval: 50,
        delta: 50,
        balance_every: 50,
        balance_threshold: 1.1,
        balance_max_moves: 1,
        balance_init_cells: "6,2".to_string(),
        ..SimConfig::default()
    };
    cfg.validate().map_err(anyhow::Error::msg)?;
    println!(
        "rebalance: {} neurons over {} ranks, skewed start {:?} (48/16 neurons), \
         threshold {}, one boundary cell per epoch",
        cfg.total_neurons(),
        cfg.ranks,
        cfg.balance_init_cells,
        cfg.balance_threshold,
    );
    println!(
        "\n{:>6} {:>14} {:>14} {:>11} {:>11}",
        "step", "cost rank0", "cost rank1", "imbalance", "migrations"
    );

    let results = run_ranks(cfg.ranks, |comm| {
        let mut state = RankState::init(&cfg, &comm);
        let mut rows = Vec::new();
        // Probe the pristine skew before any step: 48/16 neurons.
        let all = gather_all(&comm, &[state.measure_cost()]);
        let costs: Vec<f64> = all.iter().map(|b| b[0].cost()).collect();
        rows.push((0usize, costs.clone(), imbalance(&costs), state.migrations));
        for step in 0..cfg.steps {
            state.step(&cfg, &comm, step).unwrap();
            if (step + 1) % cfg.balance_every == 0 {
                // Collective probe (all ranks, same steps): the global
                // cost vector right after this epoch's migration.
                let all = gather_all(&comm, &[state.measure_cost()]);
                let costs: Vec<f64> = all.iter().map(|b| b[0].cost()).collect();
                rows.push((step + 1, costs.clone(), imbalance(&costs), state.migrations));
                // The acceptance invariants hold after every epoch.
                state.store.check_invariants().unwrap();
                state.plan.check_against(&state.store).unwrap();
            }
        }
        (rows, state.pop.len())
    });

    let (rows, _) = &results[0];
    for (step, costs, imb, migrations) in rows {
        println!(
            "{:>6} {:>14.0} {:>14.0} {:>11.3} {:>11}",
            step, costs[0], costs[1], imb, migrations
        );
    }
    let first = rows.first().unwrap().2;
    let last = rows.last().unwrap().2;
    let (n0, n1) = (results[0].1, results[1].1);
    println!(
        "\npopulations: rank0 {} / rank1 {} neurons (started 48/16); \
         imbalance {:.3} -> {:.3}",
        n0, n1, first, last
    );
    assert!(last < first, "imbalance must drop after rebalancing");
    assert_eq!(n0 + n1, cfg.total_neurons());
    assert!(n0 < 48 && n1 > 16, "neurons must have migrated");
    println!("rebalance OK: computation moved to where the load was light.");
    Ok(())
}
