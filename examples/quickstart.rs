//! Quickstart: the smallest meaningful ILMI run.
//!
//! Simulates a 4-rank, 1024-neuron network for 1000 steps (10
//! connectivity updates) with the paper's NEW algorithms — the
//! location-aware Barnes–Hut and the frequency-based spike exchange —
//! then prints the phase breakdown and network statistics.
//!
//!     cargo run --release --example quickstart

use ilmi::config::SimConfig;
use ilmi::coordinator::run_simulation;

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig {
        ranks: 4,
        neurons_per_rank: 256,
        steps: 1000,
        ..SimConfig::default()
    };
    println!(
        "quickstart: {} ranks x {} neurons, {} steps ({} connectivity updates), theta={}",
        cfg.ranks,
        cfg.neurons_per_rank,
        cfg.steps,
        cfg.steps / cfg.plasticity_interval,
        cfg.theta
    );

    let report = run_simulation(&cfg)?;
    print!("{}", report.phase_table());

    let f = report.formation();
    println!(
        "searches {} | proposals {} | formed {} | declined {} | failed {}",
        f.searches, f.proposals, f.formed, f.declined, f.failed_searches
    );
    println!(
        "spike look-ups {} | synchronization collectives {}",
        report.total_lookups(),
        report.ranks.iter().map(|r| r.comm.collectives).sum::<u64>()
    );
    assert!(report.total_synapses() > 0, "expected the network to wire up");
    println!("quickstart OK");
    Ok(())
}
