//! Scenario branching from one saved brain — the workflow the
//! checkpoint/restore subsystem exists for (paper §I, §VI: "predict
//! brain changes after learning, lesions, or normal development").
//!
//! Instead of regrowing the connectome once per experiment (as
//! `lesion_rewiring.rs` does), this example:
//!
//!   1. grows ONE network to (near-)equilibrium and snapshots it
//!      (`--checkpoint-every` machinery, one `.ilmisnap` file);
//!   2. branches a CONTROL run from the snapshot through the public
//!      `resume_simulation` API — bit-exact continuation;
//!   3. branches a LESION run from the *same* snapshot through the
//!      per-rank `RankState::restore` API, silencing rank 0's neurons
//!      before continuing — same brain, different protocol;
//!   4. shows the two scenarios diverging, and that the lesioned
//!      tissue ends fully disconnected while the control keeps its
//!      connectivity.
//!
//!     cargo run --release --example branch_scenarios

use ilmi::comm::run_ranks;
use ilmi::config::SimConfig;
use ilmi::coordinator::{resume_simulation, RankState};
use ilmi::snapshot::{snapshot_file_name, Snapshot};

const LESION_RANK: usize = 0;
const GROW_STEPS: usize = 8_000;
const BRANCH_STEPS: usize = 4_000;

/// (synapses between healthy neurons, synapses touching the lesion
/// rank, mean calcium of this rank) — counted on the axonal side, so
/// summing over ranks counts each synapse exactly once.
fn census(state: &RankState, rank: usize, npr: u64) -> (usize, usize, f64) {
    let mut healthy = 0usize;
    let mut touching = 0usize;
    let src_lesioned = rank == LESION_RANK;
    for edges in &state.store.out_edges {
        for &tgt in edges {
            if src_lesioned || (tgt / npr) as usize == LESION_RANK {
                touching += 1;
            } else {
                healthy += 1;
            }
        }
    }
    (healthy, touching, state.pop.mean_calcium())
}

/// Continue the saved brain for `BRANCH_STEPS` via the per-rank API,
/// optionally lesioning rank 0 first. Returns per-rank census tuples.
fn run_branch(
    cfg: &SimConfig,
    snap: &Snapshot,
    lesion: bool,
) -> Vec<(usize, usize, f64)> {
    let npr = cfg.neurons_per_rank as u64;
    run_ranks(cfg.ranks, |comm| {
        let rank = comm.rank();
        let mut cfg_rank = cfg.clone();
        let mut state = RankState::restore(&cfg_rank, &comm, snap)
            .expect("snapshot restores");
        if lesion && rank == LESION_RANK {
            // Zero the synaptic elements: the next deletion phase
            // dismantles every synapse touching these neurons through
            // the normal notification protocol. Silencing the
            // background keeps them from regrowing.
            for i in 0..state.pop.len() {
                state.pop.z_ax[i] = 0.0;
                state.pop.z_den_exc[i] = 0.0;
                state.pop.z_den_inh[i] = 0.0;
                state.pop.ca[i] = 0.0;
            }
            cfg_rank.bg_mean = 0.0;
            cfg_rank.bg_std = 0.0;
        }
        for step in GROW_STEPS..GROW_STEPS + BRANCH_STEPS {
            state.step(&cfg_rank, &comm, step).unwrap();
        }
        census(&state, rank, npr)
    })
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("ilmi_branch_{}", std::process::id()));
    let cfg = SimConfig {
        ranks: 4,
        neurons_per_rank: 64,
        steps: GROW_STEPS,
        plasticity_interval: 100,
        delta: 100,
        checkpoint_every: GROW_STEPS,
        checkpoint_dir: dir.to_str().unwrap().to_string(),
        ..SimConfig::default()
    };
    println!(
        "branch scenarios: grow {} ranks x {} neurons for {} steps ONCE, then fan out \
         {}-step scenarios from the snapshot",
        cfg.ranks, cfg.neurons_per_rank, GROW_STEPS, BRANCH_STEPS
    );

    // -- 1. grow one equilibrium brain, snapshotted at the end ----------
    let grown = ilmi::coordinator::run_simulation(&cfg)?;
    println!(
        "grown: {} synapses, mean Ca {:.3} -> snapshot at step {}",
        grown.total_synapses(),
        grown.mean_calcium(),
        GROW_STEPS
    );
    let snap_path = dir.join(snapshot_file_name(GROW_STEPS as u64));
    let snap = Snapshot::read_file(&snap_path).map_err(anyhow::Error::msg)?;

    // Branch config: same dynamics, longer schedule, no checkpointing.
    let mut branch_cfg = cfg.clone();
    branch_cfg.steps = GROW_STEPS + BRANCH_STEPS;
    branch_cfg.checkpoint_every = 0;
    branch_cfg.checkpoint_dir = String::new();

    // -- 2. control scenario through the public resume API -------------
    let control_api = resume_simulation(&branch_cfg, &snap)?;

    // -- 3. the same control plus a lesion scenario through the
    //       per-rank restore API, both from the SAME snapshot ----------
    let control = run_branch(&branch_cfg, &snap, false);
    let lesion = run_branch(&branch_cfg, &snap, true);

    // The two control paths (driver resume vs manual restore+step) are
    // the same computation: their synapse totals must agree exactly.
    let control_total: usize = control.iter().map(|c| c.0 + c.1).sum();
    assert_eq!(
        control_api.total_synapses(),
        control_total,
        "resume_simulation and RankState::restore must agree bit-exactly"
    );

    let sum = |xs: &[(usize, usize, f64)], pick: fn(&(usize, usize, f64)) -> usize| -> usize {
        xs.iter().map(pick).sum()
    };
    let healthy_ca = |xs: &[(usize, usize, f64)]| -> f64 {
        let v: Vec<f64> = xs
            .iter()
            .enumerate()
            .filter(|(r, _)| *r != LESION_RANK)
            .map(|(_, c)| c.2)
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };

    println!(
        "\n{:<22} {:>16} {:>18} {:>12}",
        "scenario", "healthy synapses", "touching rank 0", "healthy Ca"
    );
    for (name, xs) in [("control", &control), ("lesion rank 0", &lesion)] {
        println!(
            "{:<22} {:>16} {:>18} {:>12.3}",
            name,
            sum(xs, |c| c.0),
            sum(xs, |c| c.1),
            healthy_ca(xs)
        );
    }

    // Divergence: same initial brain, different outcomes.
    assert_eq!(
        sum(&lesion, |c| c.1),
        0,
        "lesioned neurons must end fully disconnected"
    );
    assert!(
        sum(&control, |c| c.1) > 0,
        "control must keep synapses touching rank 0"
    );
    assert_ne!(
        sum(&control, |c| c.0),
        sum(&lesion, |c| c.0),
        "scenarios should diverge in healthy-tissue connectivity"
    );
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "\nbranch scenarios OK: one grown brain, two divergent futures — no regrowing."
    );
    Ok(())
}
