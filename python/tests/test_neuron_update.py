"""L1 correctness: Pallas neuron_update kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute layer: everything the
Rust runtime executes is the lowering of exactly this kernel.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import neuron_update as nu
from compile.kernels import ref


def default_params():
    p = np.zeros(ref.NUM_PARAMS, dtype=np.float32)
    p[ref.P_A] = 0.02
    p[ref.P_B] = 0.2
    p[ref.P_C] = -65.0
    p[ref.P_D] = 8.0
    p[ref.P_DT] = 1.0
    p[ref.P_TAU_CA] = 100.0
    p[ref.P_BETA_CA] = 0.01
    p[ref.P_NU] = 0.001
    p[ref.P_EPS] = 0.7
    p[ref.P_ETA_AX] = 0.1
    p[ref.P_ETA_DEN] = 0.0
    p[ref.P_VSPIKE] = 30.0
    p[ref.P_ISCALE] = 10.0
    return p


def random_state(rng, n):
    return dict(
        v=rng.uniform(-80.0, 25.0, n).astype(np.float32),
        u=rng.uniform(-20.0, 10.0, n).astype(np.float32),
        ca=rng.uniform(0.0, 1.2, n).astype(np.float32),
        z_ax=rng.uniform(0.0, 5.0, n).astype(np.float32),
        z_de=rng.uniform(0.0, 5.0, n).astype(np.float32),
        z_di=rng.uniform(0.0, 5.0, n).astype(np.float32),
        i_syn=rng.uniform(-3.0, 3.0, n).astype(np.float32),
        noise=rng.normal(5.0, 1.0, n).astype(np.float32),
    )


def run_both(state, params, block=None):
    args = [state[k] for k in
            ("v", "u", "ca", "z_ax", "z_de", "z_di", "i_syn", "noise")]
    n = args[0].shape[0]
    blk = block or min(nu.BLOCK, n)
    got = nu.neuron_update(*[jnp.asarray(a) for a in args],
                           jnp.asarray(params), block=blk)
    want = ref.neuron_update_ref(*[jnp.asarray(a) for a in args],
                                 jnp.asarray(params))
    return got, want


def assert_matches(got, want, atol=1e-4, rtol=1e-5):
    # f32 + different fusion order between interpret-mode Pallas and the
    # jnp oracle -> last-ulp differences on ~1e2-magnitude values.
    names = ["v", "u", "ca", "z_ax", "z_de", "z_di", "fired"]
    for g, w, name in zip(got, want, names):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=atol, rtol=rtol, err_msg=name)


def test_kernel_matches_ref_single_block():
    rng = np.random.default_rng(0)
    got, want = run_both(random_state(rng, 256), default_params())
    assert_matches(got, want)


def test_kernel_matches_ref_multi_block():
    rng = np.random.default_rng(1)
    got, want = run_both(random_state(rng, 512), default_params(), block=128)
    assert_matches(got, want)


def test_model_entrypoint_matches_ref():
    rng = np.random.default_rng(2)
    state = random_state(rng, 256)
    args = [jnp.asarray(state[k]) for k in
            ("v", "u", "ca", "z_ax", "z_de", "z_di", "i_syn", "noise")]
    params = jnp.asarray(default_params())
    got = model.electrical_update(*args, params)
    want = model.electrical_update_ref(*args, params)
    assert_matches(got, want)


def test_spike_resets_state():
    """A neuron pushed far above threshold fires, resets v to c, bumps u by d."""
    params = default_params()
    n = 128
    state = {k: np.zeros(n, dtype=np.float32) for k in
             ("v", "u", "ca", "z_ax", "z_de", "z_di", "i_syn", "noise")}
    state["v"][:] = 29.0
    state["noise"][:] = 1000.0  # guaranteed spike
    got, _ = run_both(state, params)
    fired = np.asarray(got[6])
    assert (fired == 1.0).all()
    np.testing.assert_allclose(np.asarray(got[0]), params[ref.P_C])


def test_subthreshold_does_not_fire():
    params = default_params()
    n = 128
    state = {k: np.zeros(n, dtype=np.float32) for k in
             ("v", "u", "ca", "z_ax", "z_de", "z_di", "i_syn", "noise")}
    state["v"][:] = -65.0
    state["u"][:] = -13.0
    got, _ = run_both(state, params)
    assert (np.asarray(got[6]) == 0.0).all()


def test_calcium_decays_without_spikes():
    params = default_params()
    n = 128
    state = {k: np.zeros(n, dtype=np.float32) for k in
             ("v", "u", "ca", "z_ax", "z_de", "z_di", "i_syn", "noise")}
    state["v"][:] = -65.0
    state["u"][:] = -13.0
    state["ca"][:] = 0.5
    got, _ = run_both(state, params)
    ca = np.asarray(got[2])
    expected = 0.5 - 0.5 / params[ref.P_TAU_CA]
    np.testing.assert_allclose(ca, expected, rtol=1e-6)


def test_elements_never_negative():
    params = default_params()
    rng = np.random.default_rng(3)
    state = random_state(rng, 256)
    state["z_ax"][:] = 0.0  # retraction would go below zero
    state["ca"][:] = 2.0  # far above target -> shrink
    got, _ = run_both(state, params)
    for idx in (3, 4, 5):
        assert (np.asarray(got[idx]) >= 0.0).all()


def test_growth_curve_zeros_at_eta_and_eps():
    g_eta = ref.growth_curve(jnp.float32(0.1), 0.001, 0.1, 0.7)
    g_eps = ref.growth_curve(jnp.float32(0.7), 0.001, 0.1, 0.7)
    assert abs(float(g_eta)) < 1e-8
    assert abs(float(g_eps)) < 1e-8


def test_growth_curve_sign_structure():
    nu_, eta, eps = 0.001, 0.1, 0.7
    mid = ref.growth_curve(jnp.float32(0.4), nu_, eta, eps)
    below = ref.growth_curve(jnp.float32(0.0), nu_, eta, eps)
    above = ref.growth_curve(jnp.float32(1.0), nu_, eta, eps)
    assert float(mid) > 0.0  # grow between eta and eps
    assert float(below) < 0.0  # retract below eta
    assert float(above) < 0.0  # retract above eps (homeostasis)


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([64, 128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(n_blocks, block, seed):
    """Property sweep: any (shape, seed) combination matches the oracle."""
    rng = np.random.default_rng(seed)
    state = random_state(rng, n_blocks * block)
    got, want = run_both(state, default_params(), block=block)
    assert_matches(got, want)


@settings(max_examples=10, deadline=None)
@given(
    tau=st.floats(min_value=10.0, max_value=1000.0),
    beta=st.floats(min_value=0.0, max_value=0.1),
    target=st.floats(min_value=0.3, max_value=1.0),
)
def test_kernel_matches_ref_param_sweep(tau, beta, target):
    """Parameter-space sweep: the kernel tracks the oracle for any params."""
    params = default_params()
    params[ref.P_TAU_CA] = tau
    params[ref.P_BETA_CA] = beta
    params[ref.P_EPS] = target
    rng = np.random.default_rng(42)
    got, want = run_both(random_state(rng, 128), params)
    assert_matches(got, want)


def test_rejects_non_multiple_batch():
    rng = np.random.default_rng(4)
    state = random_state(rng, 100)
    with pytest.raises(AssertionError):
        run_both(state, default_params(), block=64)
