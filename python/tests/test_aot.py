"""AOT lowering sanity: HLO text artifacts parse-ably produced."""

import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_lower_neuron_update_produces_hlo_text():
    text = aot.lower_neuron_update(256)
    assert "ENTRY" in text
    assert "f32[256]" in text
    # return_tuple=True -> the root is a tuple of the 7 outputs
    assert text.count("f32[256]") >= 7


def test_lower_gauss_probs_produces_hlo_text():
    text = aot.lower_gauss_probs(1024)
    assert "ENTRY" in text
    assert "f32[1024]" in text


def test_lowering_is_deterministic():
    assert aot.lower_neuron_update(256) == aot.lower_neuron_update(256)


def test_neuron_batches_cover_paper_grid():
    """The paper's weak-scaling grid uses 1024..65536 neurons per rank."""
    for n in (1024, 4096, 16384, 65536):
        assert n in aot.NEURON_BATCHES


def test_lowered_module_executes():
    """The jitted L2 entry point (what gets lowered) actually runs."""
    n = 256
    rng = np.random.default_rng(0)
    vec = lambda: jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    params = np.zeros(ref.NUM_PARAMS, dtype=np.float32)
    params[ref.P_DT] = 1.0
    params[ref.P_TAU_CA] = 100.0
    params[ref.P_EPS] = 0.7
    params[ref.P_VSPIKE] = 30.0
    out = model.electrical_update(vec(), vec(), vec(), vec(), vec(), vec(),
                                  vec(), vec(), jnp.asarray(params))
    assert len(out) == 7
    assert out[0].shape == (n,)
