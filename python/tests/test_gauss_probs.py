"""L1 correctness: Pallas gauss_probs kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import gauss_probs as gp
from compile.kernels import ref


def run_kernel(src, sigma, pos, vac, block=None):
    n = pos.shape[0]
    blk = block or min(gp.BLOCK, n)
    return np.asarray(gp.gauss_probs(
        jnp.asarray(src), jnp.asarray([sigma], dtype=jnp.float32),
        jnp.asarray(pos[:, 0]), jnp.asarray(pos[:, 1]),
        jnp.asarray(pos[:, 2]), jnp.asarray(vac), block=blk))


def run_ref(src, sigma, pos, vac):
    return np.asarray(ref.gauss_probs_ref(
        jnp.asarray(src), jnp.asarray(pos), jnp.asarray(vac),
        jnp.float32(sigma)))


def random_case(rng, n, box=1000.0):
    src = rng.uniform(0, box, 3).astype(np.float32)
    pos = rng.uniform(0, box, (n, 3)).astype(np.float32)
    vac = rng.integers(0, 5, n).astype(np.float32)
    return src, pos, vac


def test_kernel_matches_ref():
    rng = np.random.default_rng(0)
    src, pos, vac = random_case(rng, 256)
    np.testing.assert_allclose(run_kernel(src, 750.0, pos, vac),
                               run_ref(src, 750.0, pos, vac),
                               rtol=1e-6, atol=1e-7)


def test_kernel_matches_ref_multi_block():
    rng = np.random.default_rng(1)
    src, pos, vac = random_case(rng, 512)
    np.testing.assert_allclose(run_kernel(src, 750.0, pos, vac, block=128),
                               run_ref(src, 750.0, pos, vac),
                               rtol=1e-6, atol=1e-7)


def test_model_entrypoint_matches_ref():
    rng = np.random.default_rng(2)
    src, pos, vac = random_case(rng, 256)
    (got,) = model.connection_probs(
        jnp.asarray(src), jnp.asarray([750.0], dtype=jnp.float32),
        jnp.asarray(pos[:, 0]), jnp.asarray(pos[:, 1]),
        jnp.asarray(pos[:, 2]), jnp.asarray(vac))
    np.testing.assert_allclose(np.asarray(got), run_ref(src, 750.0, pos, vac),
                               rtol=1e-6, atol=1e-7)


def test_zero_vacancy_zero_probability():
    rng = np.random.default_rng(3)
    src, pos, vac = random_case(rng, 128)
    vac[:] = 0.0
    assert (run_kernel(src, 750.0, pos, vac) == 0.0).all()


def test_probability_decays_with_distance():
    src = np.zeros(3, dtype=np.float32)
    n = 128
    pos = np.zeros((n, 3), dtype=np.float32)
    pos[:, 0] = np.linspace(0.0, 2000.0, n)
    vac = np.ones(n, dtype=np.float32)
    probs = run_kernel(src, 750.0, pos, vac)
    assert (np.diff(probs) <= 1e-9).all()


def test_at_source_probability_equals_vacancy():
    src = np.array([5.0, 5.0, 5.0], dtype=np.float32)
    pos = np.tile(src, (128, 1))
    vac = np.full(128, 3.0, dtype=np.float32)
    np.testing.assert_allclose(run_kernel(src, 750.0, pos, vac), 3.0,
                               rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([64, 256]),
    sigma=st.floats(min_value=1.0, max_value=5000.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(n_blocks, block, sigma, seed):
    rng = np.random.default_rng(seed)
    src, pos, vac = random_case(rng, n_blocks * block)
    np.testing.assert_allclose(run_kernel(src, sigma, pos, vac, block=block),
                               run_ref(src, sigma, pos, vac),
                               rtol=1e-5, atol=1e-7)
