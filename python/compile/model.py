"""L2 JAX model: the per-rank batched compute graph the Rust runtime runs.

Two exported computations:

* `electrical_update` — one simulation step for all neurons a rank owns:
  the fused L1 `neuron_update` Pallas kernel over the SoA state. This is
  the paper's "Actual activity update" + "Update of synaptic elements"
  phases, batched. Rust supplies the synaptic input (assembled from the
  spike-exchange phase) and the background noise (its own PRNG, so the
  artifact stays stateless and deterministic).
* `connection_probs` — one Gaussian probability row (L1 `gauss_probs`),
  used by the direct O(n^2) baseline and by tests.

Both are lowered once per batch size by `aot.py` to HLO text; Python is
never on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import gauss_probs as gp
from .kernels import neuron_update as nu
from .kernels import ref


def electrical_update(v, u, ca, z_ax, z_de, z_di, i_syn, noise, params):
    """Fused per-step state transition (see kernels.ref for the math)."""
    block = min(nu.BLOCK, v.shape[0])
    return nu.neuron_update(v, u, ca, z_ax, z_de, z_di, i_syn, noise,
                            params, block=block)


def connection_probs(src_pos, sigma, tx, ty, tz, vac):
    """Gaussian connection-probability row for one searching axon."""
    block = min(gp.BLOCK, tx.shape[0])
    return (gp.gauss_probs(src_pos, sigma, tx, ty, tz, vac, block=block),)


def electrical_update_ref(v, u, ca, z_ax, z_de, z_di, i_syn, noise, params):
    """Pure-jnp reference of `electrical_update` (no Pallas) for tests."""
    return ref.neuron_update_ref(v, u, ca, z_ax, z_de, z_di, i_syn, noise,
                                 params)
