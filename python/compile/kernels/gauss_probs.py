"""L1 Pallas kernel: Gaussian connection-probability row.

Computes, for one searching axon at `src_pos`, the un-normalised MSP
connection probability against every candidate dendrite:

    p_j = vac_j * exp(-|x_j - src|^2 / sigma^2)

This is the inner product the direct O(n^2) connectivity update evaluates
n times per plasticity step; the Barnes-Hut path approximates exactly this
row. The kernel is the oracle for distribution tests of both Barnes-Hut
variants and powers the `direct` baseline in the bench harness.

Tiling: candidate positions arrive as three separate coordinate arrays
(SoA) so each tile is a clean (BLOCK,) vector; the scalar source position
is broadcast from a (3,) operand into every grid step (one VMEM-resident
copy reused across all target tiles — the data stays put, the small thing
moves, which is the paper's own trick at cluster level).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _kernel(src_ref, sigma_ref, tx_ref, ty_ref, tz_ref, vac_ref, out_ref):
    src = src_ref[...]
    dx = tx_ref[...] - src[0]
    dy = ty_ref[...] - src[1]
    dz = tz_ref[...] - src[2]
    d2 = dx * dx + dy * dy + dz * dz
    sigma = sigma_ref[0]
    out_ref[...] = vac_ref[...] * jnp.exp(-d2 / (sigma * sigma))


@functools.partial(jax.jit, static_argnames=("block",))
def gauss_probs(src_pos, sigma, tx, ty, tz, vac, *, block=BLOCK):
    """Probability row over n candidates (n a multiple of `block`).

    src_pos: f32 (3,); sigma: f32 (1,); tx/ty/tz/vac: f32 (n,).
    """
    n = tx.shape[0]
    assert n % block == 0, f"batch {n} not a multiple of block {block}"
    grid = (n // block,)
    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    src_spec = pl.BlockSpec((3,), lambda i: (0,))
    sig_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[src_spec, sig_spec] + [vec_spec] * 4,
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(src_pos, sigma, tx, ty, tz, vac)
