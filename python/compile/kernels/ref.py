"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: `neuron_update_ref` defines the
fused MSP electrical/plasticity state transition (Izhikevich + calcium +
three Gaussian growth curves), and `gauss_probs_ref` defines the pairwise
Gaussian connection-probability row used by the direct O(n^2) baseline.

The Rust native fallback (`rust/src/neuron/izhikevich.rs`) mirrors this
math op-for-op in f32; the integration test `integration_runtime.rs`
checks the lowered HLO against the Rust implementation.
"""

import jax.numpy as jnp

# Indices into the (16,) f32 parameter vector shared by all layers.
# Keep in sync with `rust/src/neuron/params.rs` (PARAM_* constants).
P_A = 0  # Izhikevich recovery time scale
P_B = 1  # Izhikevich recovery sensitivity
P_C = 2  # Izhikevich reset potential (mV)
P_D = 3  # Izhikevich reset recovery increment
P_DT = 4  # integration step (ms)
P_TAU_CA = 5  # calcium decay constant (steps)
P_BETA_CA = 6  # calcium increment per spike
P_NU = 7  # synaptic-element growth rate (elements/step)
P_EPS = 8  # target calcium (growth-curve zero, right)
P_ETA_AX = 9  # minimal calcium for axonal growth (zero, left)
P_ETA_DEN = 10  # minimal calcium for dendritic growth (zero, left)
P_VSPIKE = 11  # spike threshold (mV)
P_ISCALE = 12  # synaptic-input scaling
NUM_PARAMS = 16

SQRT_LN2 = 0.8325546111576977  # sqrt(ln 2)


def growth_curve(ca, nu, eta, eps):
    """Butz & van Ooyen (2013) Gaussian growth curve.

    dz = nu * (2 * exp(-((ca - xi)/zeta)^2) - 1), with xi/zeta chosen so
    the curve is exactly zero at ca = eta and ca = eps, positive between,
    negative outside (homeostasis towards the target calcium eps).
    """
    xi = (eta + eps) / 2.0
    zeta = (eps - eta) / (2.0 * SQRT_LN2)
    g = (ca - xi) / zeta
    return nu * (2.0 * jnp.exp(-(g * g)) - 1.0)


def neuron_update_ref(v, u, ca, z_ax, z_de, z_di, i_syn, noise, params):
    """One fused MSP step for a batch of neurons (all arrays f32 (n,)).

    Returns (v', u', ca', z_ax', z_de', z_di', fired) with fired in {0,1}.
    """
    a = params[P_A]
    b = params[P_B]
    c = params[P_C]
    d = params[P_D]
    dt = params[P_DT]
    tau_ca = params[P_TAU_CA]
    beta_ca = params[P_BETA_CA]
    nu = params[P_NU]
    eps = params[P_EPS]
    eta_ax = params[P_ETA_AX]
    eta_den = params[P_ETA_DEN]
    v_spike = params[P_VSPIKE]
    i_scale = params[P_ISCALE]

    i_total = i_syn * i_scale + noise

    # Izhikevich (2003): v' = 0.04 v^2 + 5v + 140 - u + I ; u' = a(bv - u).
    v_new = v + dt * (0.04 * v * v + 5.0 * v + 140.0 - u + i_total)
    u_new = u + dt * a * (b * v - u)

    fired = (v_new >= v_spike).astype(jnp.float32)
    v_out = jnp.where(fired > 0.0, c, v_new)
    u_out = jnp.where(fired > 0.0, u_new + d, u_new)

    # Calcium trace: running, exponentially-decaying spike average.
    ca_out = ca - dt * ca / tau_ca + beta_ca * fired

    # Synaptic-element growth (axonal / excitatory-dendritic /
    # inhibitory-dendritic); element counts never go negative.
    z_ax_out = jnp.maximum(z_ax + growth_curve(ca_out, nu, eta_ax, eps), 0.0)
    z_de_out = jnp.maximum(z_de + growth_curve(ca_out, nu, eta_den, eps), 0.0)
    z_di_out = jnp.maximum(z_di + growth_curve(ca_out, nu, eta_den, eps), 0.0)

    return v_out, u_out, ca_out, z_ax_out, z_de_out, z_di_out, fired


def gauss_probs_ref(src_pos, tgt_pos, tgt_vac, sigma):
    """Gaussian connection-probability row: vac_j * exp(-|x_j - s|^2 / sigma^2).

    src_pos: (3,), tgt_pos: (n, 3), tgt_vac: (n,). The caller masks
    self-connection by zeroing its own vacancy entry.
    """
    diff = tgt_pos - src_pos[None, :]
    d2 = jnp.sum(diff * diff, axis=1)
    return tgt_vac * jnp.exp(-d2 / (sigma * sigma))
