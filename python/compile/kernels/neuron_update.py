"""L1 Pallas kernel: fused MSP neuron-state transition.

One pass over a structure-of-arrays tile of neurons performs the whole
per-step state transition the paper's "Actual activity update" and
"Update of synaptic elements" phases need: Izhikevich integration, spike
detection/reset, calcium trace, and the three Gaussian growth curves.

TPU framing (DESIGN.md SS Hardware-Adaptation): the kernel is elementwise
(VPU-bound), so the win is touching each state array exactly once per
step — block = (BLOCK,) per array, 9 input tiles + 7 output tiles of
BLOCK * 4 B each (BLOCK=1024 -> 64 KiB live in VMEM, far under budget),
one HBM<->VMEM round trip instead of five separate elementwise passes.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = 1024


def _kernel(v_ref, u_ref, ca_ref, zax_ref, zde_ref, zdi_ref, isyn_ref,
            noise_ref, params_ref,
            vo_ref, uo_ref, cao_ref, zaxo_ref, zdeo_ref, zdio_ref, fo_ref):
    params = params_ref[...]
    out = ref.neuron_update_ref(
        v_ref[...], u_ref[...], ca_ref[...],
        zax_ref[...], zde_ref[...], zdi_ref[...],
        isyn_ref[...], noise_ref[...], params,
    )
    vo_ref[...], uo_ref[...], cao_ref[...] = out[0], out[1], out[2]
    zaxo_ref[...], zdeo_ref[...], zdio_ref[...] = out[3], out[4], out[5]
    fo_ref[...] = out[6]


@functools.partial(jax.jit, static_argnames=("block",))
def neuron_update(v, u, ca, z_ax, z_de, z_di, i_syn, noise, params,
                  *, block=BLOCK):
    """Pallas-tiled fused neuron update. All state arrays f32 (n,) with n a
    multiple of `block`; params f32 (NUM_PARAMS,) broadcast to every tile."""
    n = v.shape[0]
    assert n % block == 0, f"batch {n} not a multiple of block {block}"
    grid = (n // block,)
    state_spec = pl.BlockSpec((block,), lambda i: (i,))
    param_spec = pl.BlockSpec((ref.NUM_PARAMS,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32) for _ in range(7)]
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[state_spec] * 8 + [param_spec],
        out_specs=[state_spec] * 7,
        out_shape=out_shape,
        interpret=True,
    )(v, u, ca, z_ax, z_de, z_di, i_syn, noise, params)
