"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published `xla` 0.1.6 crate links) rejects (`proto.id() <=
INT_MAX`). The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Lowered with `return_tuple=True`; the Rust side unwraps with `to_tuple*`.

Artifacts (one per batch size, so Rust pads a rank's neuron count to the
next available size):

    artifacts/neuron_update_b{N}.hlo.txt   N in NEURON_BATCHES
    artifacts/gauss_probs_n{N}.hlo.txt     N in PROB_BATCHES
    artifacts/manifest.txt                 one line per artifact

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

NEURON_BATCHES = [256, 1024, 4096, 16384, 65536]
PROB_BATCHES = [1024, 4096, 16384]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_neuron_update(n: int) -> str:
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    par = jax.ShapeDtypeStruct((ref.NUM_PARAMS,), jnp.float32)
    args = [vec] * 8 + [par]
    return to_hlo_text(jax.jit(model.electrical_update).lower(*args))


def lower_gauss_probs(n: int) -> str:
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    src = jax.ShapeDtypeStruct((3,), jnp.float32)
    sig = jax.ShapeDtypeStruct((1,), jnp.float32)
    return to_hlo_text(
        jax.jit(model.connection_probs).lower(src, sig, vec, vec, vec, vec)
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--max-neuron-batch", type=int, default=65536,
                    help="skip neuron batches above this size")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for n in NEURON_BATCHES:
        if n > args.max_neuron_batch:
            continue
        name = f"neuron_update_b{n}.hlo.txt"
        text = lower_neuron_update(n)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        manifest.append(f"neuron_update {n} {name}")
        print(f"wrote {name} ({len(text)} chars)")
    for n in PROB_BATCHES:
        name = f"gauss_probs_n{n}.hlo.txt"
        text = lower_gauss_probs(n)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        manifest.append(f"gauss_probs {n} {name}")
        print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
